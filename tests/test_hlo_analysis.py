"""The HLO static analyzer must count known-FLOP programs exactly
(it is the roofline's measurement instrument)."""
import jax
import jax.numpy as jnp

from repro.launch import hlo_analysis


def test_scan_matmul_flops_exact():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    comp = jax.jit(f).lower(x, w).compile()
    res = hlo_analysis.analyze(comp.as_text())
    expected = 7 * 2 * 128 * 256 * 256
    assert res["flops"] == expected
    # bytes: at least the dot operands+outputs each iteration
    assert res["bytes"] >= 7 * (2 * 128 * 256 + 256 * 256) * 4


def test_nested_scan_flops():
    def f(x, w):
        def outer(c, _):
            def inner(d, _):
                return d @ w, None
            y, _ = jax.lax.scan(inner, c, None, length=3)
            return y, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    comp = jax.jit(f).lower(x, w).compile()
    res = hlo_analysis.analyze(comp.as_text())
    assert res["flops"] == 5 * 3 * 2 * 64 * 64 * 64


def test_no_collectives_on_single_device():
    f = lambda x: x @ x
    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    comp = jax.jit(f).lower(x).compile()
    res = hlo_analysis.analyze(comp.as_text())
    assert res["collective_bytes"] == 0
    assert res["flops"] == 2 * 32 * 32 * 32
