"""Kernel validation sweep: every Pallas kernel vs its oracle across a
shape grid, max-abs-error reported. (Wall-time is meaningless in
interpret mode on CPU — correctness is the deliverable here; the TPU
perf story lives in the roofline analysis.)"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.ff_dense import ff_dense
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba2_ssd import mamba2_ssd


def run():
    """Prints the sweep and returns the worst max-abs error across every
    kernel/shape, so run.py can fail loudly on a regression."""
    worst = 0.0
    key = jax.random.PRNGKey(0)
    print("ff_dense:")
    for M, K, N in [(64, 784, 2000), (128, 3072, 400), (256, 256, 256)]:
        x = jax.random.normal(key, (M, K))
        w = jax.random.normal(key, (K, N)) * K ** -0.5
        b = jnp.zeros((N,))
        y, g = ff_dense(x, w, b)
        yr, gr = ref.ff_dense_ref(x, w, b)
        err = max(float(jnp.abs(y - yr).max()),
                  float(jnp.abs(g - gr).max() / (float(gr.max()) + 1e-9)))
        worst = max(worst, err)
        print(f"  ({M},{K},{N}): max_err={err:.2e}")

    print("flash_attention:")
    for B, S, H, KV, hd, causal, win in [(2, 256, 8, 2, 64, True, None),
                                         (1, 256, 4, 1, 128, True, 128),
                                         (2, 128, 4, 4, 64, False, None)]:
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (B, S, H, hd))
        k = jax.random.normal(ks[1], (B, S, KV, hd))
        v = jax.random.normal(ks[2], (B, S, KV, hd))
        o = flash_attention(q, k, v, causal=causal, window=win,
                            bq=64, bk=64)
        orf = ref.flash_attention_ref(q, k, v, causal=causal, window=win)
        err = float(jnp.abs(o - orf).max())
        worst = max(worst, err)
        print(f"  B{B} S{S} H{H}/{KV} hd{hd} causal={causal} win={win}: "
              f"max_err={err:.2e}")

    print("mamba2_ssd:")
    for B, S, H, hd, N, chunk in [(2, 256, 8, 32, 64, 64),
                                  (1, 512, 4, 64, 128, 128)]:
        ks = jax.random.split(key, 4)
        xbar = jax.random.normal(ks[0], (B, S, H, hd))
        dA = -jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
        b = jax.random.normal(ks[2], (B, S, N))
        c = jax.random.normal(ks[3], (B, S, N))
        y, hT = mamba2_ssd(xbar, dA, b, c, chunk=chunk)
        yr, hTr = ref.mamba2_ssd_ref(xbar, dA, b, c)
        # scale-normalized (same convention as the ff_dense goodness
        # entry): the long-scan outputs are O(10), where float32
        # reassociation alone moves the raw max-abs past 1e-4
        err = max(float(jnp.abs(y - yr).max() /
                        (float(jnp.abs(yr).max()) + 1e-9)),
                  float(jnp.abs(hT - hTr).max() /
                        (float(jnp.abs(hTr).max()) + 1e-9)))
        worst = max(worst, err)
        print(f"  B{B} S{S} H{H} hd{hd} N{N} L{chunk}: max_err={err:.2e}")
    return worst
