"""Bounded admission queue: accept or shed, never block.

The serving loop is open-loop — arrivals keep coming whether or not the
replica keeps up — so backpressure has to be explicit: a full queue
SHEDS the request (counted, surfaced in the ``.slo`` block) instead of
blocking the generator or growing without bound. The lock is shared
with nothing else; the serve loop and any admission thread touch the
queue only through ``offer``/``take``.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import deque
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    """One scoring request and its lifecycle record.

    ``t_arrival`` is on the stream's arrival clock (seconds since serve
    start); the engine fills the wall-clock fields as the request moves
    through the loop. ``version`` is the snapshot version that scored
    it — the per-request provenance the accuracy-vs-time curve and the
    consistency audit are built from.
    """
    id: int
    x: np.ndarray
    label: int
    t_arrival: float
    t_admit: Optional[float] = None      # wall seconds since serve start
    t_done: Optional[float] = None
    version: Optional[int] = None
    pred: Optional[int] = None

    @property
    def latency(self) -> Optional[float]:
        """Queueing + batching + scoring, from ARRIVAL (open-loop: time
        spent waiting behind a burst counts, like it would for a user)."""
        if self.t_done is None:
            return None
        return self.t_done - self.t_arrival


class AdmissionQueue:
    """Bounded FIFO with shed-on-full admission control."""

    def __init__(self, capacity: int):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = int(capacity)
        self._q: deque = deque()
        self._lock = threading.Lock()
        self.stats = {"accepted": 0, "rejected": 0, "depth_peak": 0}

    def offer(self, req: Request) -> bool:
        """Admit ``req`` if there is room; False = shed (backpressure)."""
        with self._lock:
            if len(self._q) >= self.capacity:
                self.stats["rejected"] += 1
                return False
            self._q.append(req)
            self.stats["accepted"] += 1
            self.stats["depth_peak"] = max(self.stats["depth_peak"],
                                           len(self._q))
            return True

    def take(self, n: int) -> List[Request]:
        """Pop up to ``n`` requests in FIFO order (possibly empty)."""
        with self._lock:
            out = []
            while self._q and len(out) < n:
                out.append(self._q.popleft())
            return out

    def __len__(self):
        with self._lock:
            return len(self._q)

    def oldest_arrival(self) -> Optional[float]:
        """Arrival clock of the head request (None when empty) — what
        the batcher's max-wait knob is measured against."""
        with self._lock:
            return self._q[0].t_arrival if self._q else None
