"""Substrate tests: data determinism, sharding rules, CE chunking."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import data as data_lib, sharding
from repro.core import train as train_lib
from repro.models import common


def test_image_tasks_deterministic():
    a = data_lib.mnist_like(n_train=100, n_test=50)
    b = data_lib.mnist_like(n_train=100, n_test=50)
    np.testing.assert_array_equal(a.x_train, b.x_train)
    np.testing.assert_array_equal(a.y_test, b.y_test)
    assert a.x_train.shape == (100, 784)
    assert a.x_train.min() >= 0 and a.x_train.max() <= 1


def test_cifar_like_is_harder():
    """Linear probe separability: cifar-like < mnist-like (paper's gap)."""
    def probe_acc(t):
        X = np.c_[t.x_train, np.ones(len(t.x_train))]
        W = np.linalg.lstsq(X, np.eye(10)[t.y_train], rcond=None)[0]
        Xt = np.c_[t.x_test, np.ones(len(t.x_test))]
        return ((Xt @ W).argmax(1) == t.y_test).mean()

    m = probe_acc(data_lib.mnist_like(n_train=2000, n_test=500))
    c = probe_acc(data_lib.cifar_like(n_train=2000, n_test=500))
    assert m > c + 0.1


def test_shard_task_partition():
    t = data_lib.mnist_like(n_train=100, n_test=10)
    shards = [data_lib.shard_task(t, i, 4) for i in range(4)]
    total = sum(len(s.x_train) for s in shards)
    assert total == 100
    assert all(len(s.x_test) == 10 for s in shards)


def test_lm_batches_deterministic_and_in_vocab():
    a = list(data_lib.lm_batches(1000, 2, 32, 3, seed=1))
    b = list(data_lib.lm_batches(1000, 2, 32, 3, seed=1))
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
        assert x.shape == (2, 33)
        assert x.min() >= 0 and x.max() < 1000


def test_lm_has_learnable_structure():
    """Markov corpus: bigram statistics are far from uniform."""
    toks = next(iter(data_lib.lm_batches(256, 16, 512, 1, seed=0)))
    pairs = {}
    for row in toks:
        for a, b in zip(row[:-1], row[1:]):
            pairs.setdefault(int(a), set()).add(int(b))
    # average branching far below vocab size
    avg_branch = np.mean([len(v) for v in pairs.values()])
    assert avg_branch < 64


def test_ce_chunked_matches_dense(key):
    B, S, d, V = 2, 48, 16, 37
    h = jax.random.normal(key, (B, S, d))
    w = jax.random.normal(key, (V, d))
    labels = jax.random.randint(key, (B, S), 0, V)
    mask = (jax.random.uniform(key, (B, S)) > 0.3).astype(jnp.float32)
    total = train_lib._ce_chunked(h, w, labels, mask)
    logits = jnp.einsum("bsd,vd->bsv", h, w)
    lp = jax.nn.log_softmax(logits)
    ce = -jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
    np.testing.assert_allclose(total, jnp.sum(ce * mask), rtol=1e-5)


def test_ce_chunked_grads_match(key):
    B, S, d, V = 2, 32, 8, 11
    h = jax.random.normal(key, (B, S, d))
    w = jax.random.normal(key, (V, d))
    labels = jax.random.randint(key, (B, S), 0, V)
    mask = jnp.ones((B, S))

    g1 = jax.grad(lambda hh: train_lib._ce_chunked(hh, w, labels, mask))(h)

    def dense(hh):
        lp = jax.nn.log_softmax(jnp.einsum("bsd,vd->bsv", hh, w))
        ce = -jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
        return jnp.sum(ce * mask)

    g2 = jax.grad(dense)(h)
    np.testing.assert_allclose(g1, g2, rtol=2e-4, atol=2e-5)


def test_param_specs_divisible_on_production_mesh():
    """Every rule-produced spec must divide the actual param shapes for
    every assigned arch on the 16x16 mesh (validated abstractly)."""
    from repro.configs import get_config, list_configs
    from repro.models import transformer

    class FakeMesh:
        shape = {"data": 16, "model": 16, "pod": 2}
        axis_names = ("data", "model")

    mesh = FakeMesh()
    for arch in list_configs():
        cfg = get_config(arch)
        p = jax.eval_shape(lambda k: transformer.init(k, cfg),
                           jax.random.PRNGKey(0))
        specs = sharding.param_specs(p, mesh)
        for (path, leaf), (_, spec) in zip(
                jax.tree_util.tree_flatten_with_path(p)[0],
                jax.tree_util.tree_flatten_with_path(
                    specs, is_leaf=lambda x: isinstance(
                        x, jax.sharding.PartitionSpec))[0]):
            for dim, name in zip(leaf.shape, tuple(spec)):
                if name is None:
                    continue
                size = 1
                for n in (name if isinstance(name, tuple) else (name,)):
                    size *= mesh.shape[n]
                assert dim % size == 0, (arch, path, leaf.shape, spec)


def test_rms_norm_properties(key):
    x = jax.random.normal(key, (4, 32)) * 5
    y = common.rms_normalize(x)
    np.testing.assert_allclose(jnp.mean(y * y, -1), 1.0, rtol=1e-4)
