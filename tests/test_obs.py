"""Observability subsystem tests: tracer core (nesting, thread safety,
the zero-overhead no-op), exporter registry + Chrome/JSONL schemas, the
critical-path analyzer on a hand-built DAG trace, and the executor /
api integration (records-from-spans bit-compat, resilience timers
folded onto counters, the ``--trace`` CLI flag).

Like tests/test_pff_exec.py, the real multi-device invariant check
(critical path <= measured makespan <= serial bound on an N=4 run)
happens in ONE subprocess — ``python -m repro.obs.analyze`` — because
conftest keeps the in-process runner on a single CPU device. The
in-process executor tests hand the same device to N logical nodes.
"""
import json
import os
import subprocess
import sys
import threading

import pytest

from repro.obs import analyze as analyze_lib
from repro.obs import export as export_lib
from repro.obs import trace as trace_lib

_SRC = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                    os.pardir, "src")


# ---------------------------------------------------------------------------
# Tracer core
# ---------------------------------------------------------------------------

def test_span_context_manager_nests_and_orders():
    tr = trace_lib.Tracer()
    with tr.span("outer", a=1):
        with tr.span("inner"):
            pass
    spans = tr.snapshot()
    # inner closes first (spans append at close time)
    assert [s.name for s in spans] == ["inner", "outer"]
    outer = spans[1]
    assert outer.attrs == {"a": 1}
    assert outer.t0 <= spans[0].t0 and outer.t1 >= spans[0].t1
    assert outer.duration >= 0


def test_manual_spans_events_counters():
    tr = trace_lib.Tracer(meta={"who": "test"})
    t0 = tr.now()
    sp = tr.add_span("task:train", t0, kind="train", layer=0, chapter=1)
    assert sp.t1 >= sp.t0 and sp.thread == threading.current_thread().name
    tr.event("handoff:prefetch_hit", node=2)
    tr.counter("recovery_time_s", 0.25)
    tr.counter("recovery_time_s", 0.5)
    d = tr.to_dict()
    assert d["meta"] == {"who": "test"}
    assert d["spans"][0]["attrs"]["layer"] == 0
    assert d["events"][0]["name"] == "handoff:prefetch_hit"
    assert d["counters"] == {"recovery_time_s": pytest.approx(0.75)}


def test_snapshot_start_returns_only_new_spans():
    tr = trace_lib.Tracer()
    tr.add_span("a", 0.0, 1.0)
    mark = tr.span_count()
    tr.add_span("b", 1.0, 2.0)
    assert [s.name for s in tr.snapshot(start=mark)] == ["b"]
    assert [s.name for s in tr.snapshot()] == ["a", "b"]


def test_thread_safety_hammer():
    tr = trace_lib.Tracer()
    n_threads, n_iter = 8, 200

    def work(i):
        for j in range(n_iter):
            with tr.span(f"w{i}", j=j):
                pass
            tr.event(f"e{i}")
            tr.counter("total", 1.0)

    threads = [threading.Thread(target=work, args=(i,), name=f"t{i}")
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert tr.span_count() == n_threads * n_iter
    assert len(tr.events) == n_threads * n_iter
    assert tr.counters["total"] == pytest.approx(n_threads * n_iter)
    # every record landed with its recording thread's name
    assert {s.thread for s in tr.snapshot()} == {f"t{i}"
                                                 for i in range(n_threads)}


def test_noop_is_inert_and_allocation_free():
    noop = trace_lib.NOOP
    assert not noop.enabled
    with noop.span("x", a=1) as got:
        assert got is noop
    # one shared null context manager — no per-call allocation
    assert noop.span("a") is noop.span("b")
    assert noop.add_span("x", 0.0) is None
    assert noop.event("x") is None
    assert noop.counter("x", 1.0) is None
    assert noop.now() == 0.0 and noop.span_count() == 0
    assert noop.snapshot() == []
    assert noop.to_dict() == {"meta": {}, "spans": [], "events": [],
                              "counters": {}}


def test_as_tracer_normalization():
    assert trace_lib.as_tracer(None) is trace_lib.NOOP
    assert trace_lib.as_tracer(False) is trace_lib.NOOP
    fresh = trace_lib.as_tracer(True)
    assert isinstance(fresh, trace_lib.Tracer) and fresh.block_tasks
    tr = trace_lib.Tracer(block_tasks=False)
    assert trace_lib.as_tracer(tr) is tr


# ---------------------------------------------------------------------------
# Exporters
# ---------------------------------------------------------------------------

def _sample_tracer():
    tr = trace_lib.Tracer(meta={"run": "sample"})
    tr.add_span("task:train", 0.001, 0.002, kind="train", layer=0,
                chapter=0, node=1)
    tr.add_span("run", 0.0, 0.01, schedule="all_layers")
    tr.event("handoff:prefetch_hit", node=1)
    tr.counter("checkpoint_time_s", 0.003)
    return tr


def test_chrome_export_schema(tmp_path):
    path = str(tmp_path / "trace.json")
    export_lib.export(_sample_tracer(), path, format="chrome")
    with open(path) as f:
        doc = json.load(f)
    evs = doc["traceEvents"]
    xs = [e for e in evs if e["ph"] == "X"]
    task = next(e for e in xs if e["name"] == "task:train")
    # µs on the chrome clock, pid = node, int tid
    assert task["ts"] == pytest.approx(1000.0)
    assert task["dur"] == pytest.approx(1000.0)
    assert task["pid"] == 1 and isinstance(task["tid"], int)
    assert task["args"]["layer"] == 0
    run = next(e for e in xs if e["name"] == "run")
    assert run["pid"] == 0                     # no node attr -> pid 0
    inst = [e for e in evs if e["ph"] == "i"]
    assert inst and inst[0]["name"] == "handoff:prefetch_hit"
    assert inst[0]["s"] == "t"
    assert any(e["ph"] == "M" and e["name"] == "process_name"
               for e in evs)
    assert doc["displayTimeUnit"] == "ms"
    assert doc["otherData"]["counters"]["checkpoint_time_s"] \
        == pytest.approx(0.003)


def test_jsonl_roundtrip_is_lossless(tmp_path):
    tr = _sample_tracer()
    path = str(tmp_path / "trace.jsonl")
    export_lib.export(tr, path, format="jsonl")
    reloaded = export_lib.load_jsonl(path)
    want = tr.to_dict()
    assert reloaded["meta"] == want["meta"]
    assert reloaded["counters"] == want["counters"]
    assert reloaded["spans"] == want["spans"]
    assert reloaded["events"] == want["events"]


def test_exporter_registry_surface(tmp_path):
    assert "chrome" in export_lib.names()
    assert "jsonl" in export_lib.names()
    with pytest.raises(KeyError, match="unknown trace exporter"):
        export_lib.export(_sample_tracer(), str(tmp_path / "x"),
                          format="nope")
    seen = {}
    export_lib.register_exporter(
        "test_fmt", lambda trace, path: seen.update(path=path,
                                                    n=len(trace["spans"])))
    try:
        with pytest.raises(ValueError, match="already registered"):
            export_lib.register_exporter("test_fmt", lambda t, p: None)
        export_lib.export(_sample_tracer(), str(tmp_path / "y"),
                          format="test_fmt")
        assert seen["n"] == 2
    finally:
        export_lib.EXPORTERS.unregister("test_fmt")
    assert "test_fmt" not in export_lib.names()


# ---------------------------------------------------------------------------
# Analyzer on a hand-built trace (known critical path)
# ---------------------------------------------------------------------------

def _synthetic_trace():
    """2 layers x 2 chapters on 2 nodes. Durations make the heavy chain
    train(0,0) -> train(1,0) -> train(1,1) = 1.0 + 2.0 + 0.7 = 3.7s the
    critical path (the alternative through train(0,1) is 2.2s)."""
    def span(name, t0, t1, **attrs):
        return {"name": name, "t0": t0, "t1": t1, "thread": "main",
                "attrs": attrs}

    spans = [
        span("task:train", 0.0, 1.0, kind="train", layer=0, chapter=0,
             node=0),
        span("task:train", 1.0, 3.0, kind="train", layer=1, chapter=0,
             node=1),
        span("task:train", 1.0, 1.5, kind="train", layer=0, chapter=1,
             node=0),
        span("task:train", 3.0, 3.7, kind="train", layer=1, chapter=1,
             node=1),
        span("run", 0.0, 5.0, schedule="all_layers", num_nodes=2,
             splits=2, n_layers=2, has_head=False, has_neg=False,
             strict_neg=False),
    ]
    events = [
        # a prefetch hit inside train(1,0)'s window: cost off the path
        {"name": "handoff:prefetch_hit", "t": 2.0, "thread": "main",
         "attrs": {"node": 1}},
        # a synchronous cross-node pull inside train(1,1) — which IS on
        # the critical path
        {"name": "handoff:pull_cross", "t": 3.2, "thread": "main",
         "attrs": {"node": 1}},
        # and one inside the off-path train(0,1)
        {"name": "handoff:pull_cross", "t": 1.2, "thread": "main",
         "attrs": {"node": 0}},
    ]
    return {"meta": {}, "spans": spans, "events": events,
            "counters": {"recovery_time_s": 0.1}}


def test_analyze_synthetic_dag():
    a = analyze_lib.analyze(_synthetic_trace())
    assert a.schedule == "all_layers" and a.num_nodes == 2
    assert a.makespan == pytest.approx(5.0)
    assert a.critical_path == [("train", 0, 0), ("train", 1, 0),
                               ("train", 1, 1)]
    assert a.critical_path_s == pytest.approx(3.7)
    assert a.sum_task_s == pytest.approx(4.2)
    assert a.node_busy == {0: pytest.approx(1.5), 1: pytest.approx(2.7)}
    assert a.node_idle[0] == pytest.approx(3.5)
    assert a.handoff["prefetch_hits"] == 1
    assert a.handoff["off_critical_path"] == 1
    assert a.handoff["pulls_cross"] == 2
    # only the pull inside the on-path task counts against the makespan
    assert a.handoff["on_critical_path"] == 1
    assert a.decomposition["critical_path_s"] == pytest.approx(3.7)
    assert a.decomposition["parallel_slack_s"] == pytest.approx(0.5)
    assert a.counters == {"recovery_time_s": pytest.approx(0.1)}


def test_analyze_measured_makespan_and_invariants():
    a = analyze_lib.analyze(_synthetic_trace(), measured_makespan=4.0)
    assert a.decomposition["measured_makespan_s"] == pytest.approx(4.0)
    assert a.decomposition["makespan_gap_s"] == pytest.approx(0.3)
    assert analyze_lib.check_invariants(a, 4.0) == []
    # cp > makespan trips the lower bound
    fails = analyze_lib.check_invariants(a, 3.0)
    assert len(fails) == 1 and "critical path" in fails[0]
    # makespan > serial bound trips the upper bound...
    fails = analyze_lib.check_invariants(a, 4.5)
    assert len(fails) == 1 and "serial bound" in fails[0]
    # ...unless a measured serial run raises it (shared-core hosts)
    assert analyze_lib.check_invariants(a, 4.5,
                                        serial_makespan=4.6) == []


def test_analyze_rejects_traces_without_executor_run():
    with pytest.raises(ValueError, match="no 'run' span"):
        analyze_lib.analyze({"spans": [], "events": []})
    tr = trace_lib.Tracer()
    tr.add_span("run", 0.0, 1.0, schedule="all_layers", num_nodes=1,
                splits=1, n_layers=1)
    with pytest.raises(ValueError, match="no task"):
        analyze_lib.analyze(tr)


def test_obs_package_is_jax_free():
    """Traces must be analyzable offline where jax is absent — the
    trace/export/analyze import graph may not pull jax in."""
    r = subprocess.run(
        [sys.executable, "-c",
         "import sys; import repro.obs, repro.obs.export, "
         "repro.obs.analyze; sys.exit(1 if 'jax' in sys.modules else 0)"],
        capture_output=True, text=True,
        env={**os.environ,
             "PYTHONPATH": _SRC + os.pathsep
             + os.environ.get("PYTHONPATH", "")},
        timeout=120)
    assert r.returncode == 0, r.stderr


# ---------------------------------------------------------------------------
# Executor integration: records are a view of the task spans
# ---------------------------------------------------------------------------

def _cfg(splits=3, sizes=(784, 32, 32), **kw):
    from repro.configs.ff_mlp import FFMLPConfig
    base = dict(layer_sizes=sizes, epochs=splits * 2, splits=splits,
                neg_mode="random", classifier="goodness",
                goodness_fn="sumsq", batch_size=64, seed=0)
    base.update(kw)
    return FFMLPConfig(**base)


@pytest.fixture(scope="module")
def task():
    from repro import data as data_lib
    return data_lib.mnist_like(n_train=260, n_test=100)


def _exec_fit(cfg, task, nodes=3, schedule="all_layers", **kw):
    import jax
    from repro import api
    d0 = jax.devices()[0]
    return api.fit(cfg, task, backend="executor", schedule=schedule,
                   num_nodes=nodes, devices=[d0] * nodes, **kw)


def test_traced_records_are_the_task_spans(task):
    from repro import api
    from repro.core import pff

    cfg = _cfg()
    tr = trace_lib.Tracer()
    res = _exec_fit(cfg, task, trace=tr)
    assert res.trace is tr
    assert res.records is not None and res.profile is not None
    # re-derive records from the spans: must be the identical view
    derived = [pff.TaskRecord(s.attrs["kind"], s.attrs["layer"],
                              s.attrs["chapter"], s.duration)
               for s in tr.snapshot() if s.name.startswith("task:")]
    assert derived == res.records
    busy = [0.0] * 3
    for s in tr.snapshot():
        if s.name.startswith("task:"):
            busy[s.attrs["node"]] += s.duration
    assert busy == pytest.approx(res.profile["node_busy"])
    # and they drive the simulator identically
    sim_a = api.simulate(res, "single_layer", 3)
    sim_b = api.simulate(derived, "single_layer", 3)
    assert sim_a.makespan == sim_b.makespan
    assert sim_a.speedup == sim_b.speedup


def test_profile_flag_still_yields_records(task):
    res = _exec_fit(_cfg(), task, profile=True)
    assert res.records and res.profile and len(res.profile["node_busy"]) == 3


def test_nonblocking_tracer_keeps_overlap_and_drops_records(task):
    tr = trace_lib.Tracer(block_tasks=False)
    res = _exec_fit(_cfg(), task, trace=tr)
    assert res.trace is tr and res.records is None
    assert any(s.name.startswith("task:") for s in tr.snapshot())


def test_tracing_does_not_change_the_weight_stream(task):
    from repro.core import pff_exec
    cfg = _cfg()
    ref = _exec_fit(cfg, task)
    res = _exec_fit(cfg, task, trace=True)
    assert pff_exec.params_bit_equal(ref.params, res.params)


def test_fit_sequential_and_simulate_traced(task):
    from repro import api
    res = api.fit(_cfg(), task, backend="sequential", trace=True)
    assert any(s.name == "fit:sequential" for s in res.trace.snapshot())
    res = api.fit(_cfg(), task, backend="simulate", schedule="all_layers",
                  num_nodes=3, trace=True)
    assert any(s.name == "fit:simulate" for s in res.trace.snapshot())
    assert res.trace.snapshot()[-1].attrs["num_nodes"] == 3


# ---------------------------------------------------------------------------
# Resilience timers fold onto tracer counters (and surface on FitResult)
# ---------------------------------------------------------------------------

def test_resilience_timers_surface_on_fit_and_counters(task, tmp_path):
    from repro.core import faults

    cfg = _cfg()
    plan = faults.FaultPlan([faults.Fault("crash", task="train", layer=0,
                                          chapter=1, times=1)])
    rc = faults.ResilienceConfig(checkpoint_dir=str(tmp_path),
                                 fault_plan=plan, backoff_base_s=0.001)
    tr = trace_lib.Tracer()
    res = _exec_fit(cfg, task, resilience=rc, trace=tr)
    st = res.resilience
    assert st["retries"] == 1
    assert st["recovery_time_s"] > 0.0
    assert st["checkpoint_time_s"] > 0.0
    # the SAME accumulations land on the tracer's counters
    assert tr.counters["recovery_time_s"] == \
        pytest.approx(st["recovery_time_s"])
    assert tr.counters["checkpoint_time_s"] == \
        pytest.approx(st["checkpoint_time_s"])
    names = [e.name for e in tr.events]
    assert "resilience:retry" in names
    saves = [s for s in tr.snapshot() if s.name == "checkpoint:save"]
    assert len(saves) == cfg.splits
    assert all(s.attrs["bytes"] > 0 for s in saves)

    # kill-then-resume's other half: restore cost on a resumed run
    tr2 = trace_lib.Tracer()
    res2 = _exec_fit(cfg, task, resume_from=str(tmp_path), trace=tr2)
    st2 = res2.resilience
    assert st2["resumed_from_chapter"] is not None
    assert st2["restore_time_s"] > 0.0
    assert tr2.counters["restore_time_s"] == \
        pytest.approx(st2["restore_time_s"])
    assert any(s.name == "checkpoint:restore" for s in tr2.snapshot())


# ---------------------------------------------------------------------------
# Multi-device invariants + CLI (subprocess)
# ---------------------------------------------------------------------------

def _sub_env():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    return env


def test_analyze_selftest_invariants_n4_subprocess():
    r = subprocess.run(
        [sys.executable, "-m", "repro.obs.analyze"],
        capture_output=True, text=True, env=_sub_env(), timeout=540)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "obs.analyze selftest" in r.stdout


def test_train_cli_trace_flag(tmp_path):
    out = tmp_path / "cli_trace.jsonl"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--paper-mlp",
         "--backend", "sequential", "--epochs", "2", "--splits", "2",
         "--layers", "1", "--hidden", "16", "--n-train", "128",
         "--n-test", "64", "--trace", str(out),
         "--trace-format", "jsonl"],
        capture_output=True, text=True, env=_sub_env(), timeout=540)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert out.exists()
    trace = export_lib.load_jsonl(str(out))
    assert any(s["name"] == "fit:sequential" for s in trace["spans"])
    # unknown format is rejected at argparse level (registry choices)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--paper-mlp",
         "--trace", str(out), "--trace-format", "bogus"],
        capture_output=True, text=True, env=_sub_env(), timeout=120)
    assert r.returncode == 2 and "invalid choice" in r.stderr
