"""h2o-danube-3-4b — llama+mistral mix with sliding-window attention
[arXiv:2401.16818]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="h2o-danube-3-4b",
    arch_type="dense",
    num_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv=8,
    head_dim=120,
    d_ff=10240,
    vocab=32000,
    window=4096,              # sliding-window attention (mistral-style)
    groups=((("attn",), 24),),
    source="arXiv:2401.16818 (h2o-danube)",
))
