"""Top-level model assembly: embedding -> grouped blocks -> norm -> head.

A model is a stack of *groups*; each group is ``(pattern, repeat)`` and its
parameters are stacked on a leading ``repeat`` axis, applied with
``lax.scan`` so the HLO is O(#patterns), not O(#layers).

Three entry points:
  ``forward``     — full-sequence (train / prefill) -> logits
  ``prefill``     — full-sequence forward that also fills decode caches
  ``serve_step``  — one-token decode against caches

Encoder-decoder (``cfg.enc_dec``): the leading groups that fall inside
``cfg.enc_layers`` form the (bidirectional) encoder over the stub audio
embeddings; the rest form the decoder, cross-attending to encoder output.
VLM (``cfg.vision_tokens``): cross_attn blocks attend to the stub patch
embeddings passed as ``aux``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import blocks, common
from repro.models.mlp import NO_DIST


# ---------------------------------------------------------------------------
# Group bookkeeping
# ---------------------------------------------------------------------------

def group_infos(cfg):
    """Yields (index, pattern, repeat, is_encoder) for each group."""
    seen = 0
    out = []
    for gi, (pattern, repeat) in enumerate(cfg.groups):
        n = len(pattern) * repeat
        is_enc = bool(cfg.enc_dec) and seen + n <= cfg.enc_layers
        out.append((gi, pattern, repeat, is_enc))
        seen += n
    return out


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init(key, cfg):
    dtype = common.dtype_of(cfg)
    ks = jax.random.split(key, 3 + len(cfg.groups))
    params = {
        "embed": common.dense_init(ks[0], (cfg.padded_vocab, cfg.d_model),
                                   dtype, fan_in=cfg.d_model),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    groups = []
    for gi, (pattern, repeat) in enumerate(cfg.groups):
        def unit(k, pattern=pattern):
            kk = jax.random.split(k, len(pattern))
            return tuple(blocks.block_init(kk[i], cfg, kind)
                         for i, kind in enumerate(pattern))
        groups.append(common.stack_init(ks[3 + gi], repeat, unit))
    params["groups"] = tuple(groups)
    if cfg.enc_dec:
        params["enc_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
    if not cfg.tie_embeddings:
        params["lm_head"] = common.dense_init(
            ks[1], (cfg.d_model, cfg.padded_vocab), dtype)
    return params


def param_count(params):
    return sum(x.size for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# Apply
# ---------------------------------------------------------------------------

def unit_apply(unit_params, cfg, pattern, x, ctx):
    aux_total = jnp.zeros((), jnp.float32)
    for kind, bp in zip(pattern, unit_params):
        x, a = blocks.block_apply(bp, cfg, kind, x, ctx)
        aux_total += a
    return x, aux_total


def scan_group(gp, cfg, pattern, x, ctx, remat=False):
    dist = ctx.get("dist", NO_DIST)

    def body(carry, unit_p):
        carry = dist.constrain_batch(carry)
        y, aux = unit_apply(unit_p, cfg, pattern, carry, ctx)
        return dist.constrain_batch(y), aux

    if remat:
        body = jax.checkpoint(body)
    x, auxs = jax.lax.scan(body, x, gp)
    return x, jnp.sum(auxs)


def encode(params, cfg, aux_embed, dist=NO_DIST, remat=False):
    """Run encoder groups bidirectionally over stub frame embeddings."""
    x = aux_embed
    ctx = {"causal": False, "dist": dist}
    aux_loss = jnp.zeros((), jnp.float32)
    for gi, pattern, repeat, is_enc in group_infos(cfg):
        if not is_enc:
            continue
        x, a = scan_group(params["groups"][gi], cfg, pattern, x, ctx, remat)
        aux_loss += a
    return common.rms_norm(x, params["enc_norm"], cfg.norm_eps), aux_loss


def embed_tokens(params, cfg, tokens):
    return jnp.take(params["embed"], tokens, axis=0)


def unembed(params, cfg, x):
    x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", x, params["embed"])
    else:
        logits = x @ params["lm_head"]
    logits = common.softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    if cfg.padded_vocab != cfg.vocab:       # mask pad-row logits
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab
        logits = jnp.where(pad_mask, -1e30, logits)
    return logits


def forward(params, cfg, tokens, *, aux=None, dist=NO_DIST, remat=None):
    """tokens: (B, S) int32; aux: (B, T, d) stub embeddings (audio/vlm).

    Returns (logits (B, S, V) f32, aux_loss scalar).
    """
    remat = cfg.remat if remat is None else remat
    aux_loss = jnp.zeros((), jnp.float32)
    cross_src = aux
    if cfg.enc_dec:
        cross_src, aux_loss = encode(params, cfg, aux, dist, remat)
    x = embed_tokens(params, cfg, tokens)
    ctx = {"causal": True, "aux": cross_src, "dist": dist}
    for gi, pattern, repeat, is_enc in group_infos(cfg):
        if is_enc:
            continue
        x, a = scan_group(params["groups"][gi], cfg, pattern, x, ctx, remat)
        aux_loss += a
    return unembed(params, cfg, x), aux_loss


# ---------------------------------------------------------------------------
# Decode caches
# ---------------------------------------------------------------------------

def init_caches(cfg, batch, max_len, dtype=None):
    """Stacked (repeat-leading) caches for every decoder group."""
    dtype = dtype or common.dtype_of(cfg)
    caches = []
    for gi, pattern, repeat, is_enc in group_infos(cfg):
        if is_enc:
            caches.append(None)
            continue
        unit = tuple(
            jax.eval_shape(
                lambda kind=kind: blocks.block_cache_init(
                    cfg, kind, batch, max_len, dtype))
            for kind in pattern)
        caches.append(jax.tree.map(
            lambda s: jnp.full((repeat,) + s.shape,
                               -1 if s.dtype == jnp.int32 else 0, s.dtype),
            unit))
    return tuple(caches)


def cache_specs(cfg, batch, max_len, dtype=None):
    """ShapeDtypeStruct pytree of init_caches, for dry-run lowering."""
    dtype = dtype or common.dtype_of(cfg)
    return jax.eval_shape(lambda: init_caches(cfg, batch, max_len, dtype))


# ---------------------------------------------------------------------------
# Prefill (fills caches) and decode
# ---------------------------------------------------------------------------

def prefill(params, cfg, tokens, *, aux=None, dist=NO_DIST, max_len=None,
            last_only=False):
    """Full forward that also returns filled decode caches.

    Returns (logits, caches) — logits over all S positions, or only the
    final position with ``last_only`` (what real serving needs: at 32k
    context the full (B, S, V) logits are ~TBs; the last row is MBs).
    ``max_len`` sizes the KV caches (defaults to S; pass S + generation
    budget for real serving).
    """
    B, S = tokens.shape
    max_len = max_len or S
    aux_loss = jnp.zeros((), jnp.float32)
    cross_src = aux
    if cfg.enc_dec:
        cross_src, aux_loss = encode(params, cfg, aux, dist, remat=False)
    x = embed_tokens(params, cfg, tokens)
    ctx = {"causal": True, "aux": cross_src, "dist": dist,
           "max_len": max_len}
    caches = []
    for gi, pattern, repeat, is_enc in group_infos(cfg):
        if is_enc:
            caches.append(None)
            continue

        def body(carry, unit_p, pattern=pattern):
            h = carry
            ucaches = []
            for kind, bp in zip(pattern, unit_p):
                h, c = blocks.block_prefill(bp, cfg, kind, h, ctx)
                ucaches.append(c)
            return h, tuple(ucaches)

        x, gcache = jax.lax.scan(body, x, params["groups"][gi])
        caches.append(gcache)
    if last_only:
        x = x[:, -1]
    return unembed(params, cfg, x), tuple(caches)


def serve_step(params, cfg, caches, tokens, pos, *, dist=NO_DIST):
    """One decode step. tokens: (B,) int32; pos: scalar int32 (position of
    the new token). Returns (logits (B, V), new_caches)."""
    x = embed_tokens(params, cfg, tokens)
    ctx = {"dist": dist}
    new_caches = []
    for gi, pattern, repeat, is_enc in group_infos(cfg):
        if is_enc:
            new_caches.append(None)
            continue

        def body(carry, pc, pattern=pattern):
            h = carry
            unit_p, unit_c = pc
            ucaches = []
            for kind, bp, c in zip(pattern, unit_p, unit_c):
                h, c2 = blocks.block_decode(bp, cfg, kind, c, h, pos, ctx)
                ucaches.append(c2)
            return h, tuple(ucaches)

        x, gcache = jax.lax.scan(
            body, x, (params["groups"][gi], caches[gi]))
        new_caches.append(gcache)
    return unembed(params, cfg, x), tuple(new_caches)
