"""Dispatch layer: registry-resolved kernel impls + autotuned blocks.

Model code imports from here; tests cross-validate the paths. All three
ops share one ``impl=`` contract, resolved through the kernel impl
registry (``kernels.registry`` — new backends are registrations, not
patches here):

  impl="auto"    the tuning table's measured-fastest impl for this
                 shape bucket when one is recorded (``ff_dense`` only —
                 see ``kernels.autotune``; populate it with
                 ``make tune-smoke`` / ``benchmarks.run --only=tune``),
                 else the registry's platform default (Pallas on TPU,
                 the jnp oracle elsewhere).
  impl="pallas"  force the fused kernel (interpret mode off-TPU), with
                 tuned block shapes if the table has them.
  impl="ref"     force the jnp oracle — the bit-exactness anchor (the
                 pff-exec weight-stream matrix pins this).
  impl=<custom>  anything registered via
                 ``registry.register_kernel_impl``.

Unknown impls raise a ``ValueError`` listing the registered choices.
``ff_dense`` is fully differentiable on every builtin path (the Pallas
path carries a fused custom_vjp backward kernel, which tuned block
shapes reach too) and is the engine of the FF-MLP training hot loop
(``FFMLPConfig.kernel_impl``). The legacy ``force_pallas=`` kwarg warns
``DeprecationWarning`` and delegates to ``impl="pallas"``.
"""
from __future__ import annotations

import warnings

import jax

from repro.kernels import autotune, registry


def _platform():
    return jax.default_backend()


def _interpret():
    return _platform() != "tpu"


def _legacy_force_pallas(op, force_pallas, impl):
    """The deprecated boolean spelling of ``impl="pallas"``."""
    if force_pallas is None:
        return impl
    warnings.warn(
        f"ops.{op}(force_pallas=...) is deprecated; pass impl='pallas' "
        f"(or leave impl='auto' to let the kernel registry and tuning "
        f"table pick)", DeprecationWarning, stacklevel=3)
    return "pallas" if force_pallas else impl


def __getattr__(name):
    # live views of the registries, so CLI choices and error messages
    # track custom registrations (PEP 562 module __getattr__)
    if name == "FF_DENSE_IMPLS":
        return registry.ff_dense.choices()
    if name == "FLASH_ATTENTION_IMPLS":
        return registry.flash_attention.choices()
    if name == "MAMBA2_SSD_IMPLS":
        return registry.mamba2_ssd.choices()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def ff_dense(x, w, b, *, impl="auto", norm=False, force_pallas=None):
    """Fused (or reference) y = relu(x @ w + b), g = sum(y^2, -1).

    impl: see the module docstring — "auto" consults the persisted
    tuning table per (M, K, N, dtype, platform, norm) bucket at trace
    time, so a populated table makes "auto" mean "fastest measured
    correct impl on this platform". Differentiable under jax.grad on
    every builtin path.

    norm=True: y is returned length-normalized (Hinton's inter-layer
    hand-off) — on the Pallas path the divide runs in the kernel
    epilogue, on the ref path in the jnp oracle; g stays the RAW
    pre-norm goodness on both.
    """
    impl = _legacy_force_pallas("ff_dense", force_pallas, impl)
    M, K = x.shape
    N = w.shape[1]
    blocks = None
    if impl == "auto":
        entry = autotune.lookup("ff_dense", M, K, N, x.dtype,
                                _platform(), norm=norm)
        if entry is not None:
            impl = entry["impl"]
            blocks = autotune.entry_blocks(entry)
        else:
            impl = registry.ff_dense.resolve(_platform()).name
    elif registry.ff_dense.get(impl).tunable:
        # a forced tunable impl still benefits from tuned block shapes
        entry = autotune.lookup("ff_dense", M, K, N, x.dtype,
                                _platform(), norm=norm)
        if entry is not None:
            blocks = autotune.entry_blocks(entry)
    kimpl = registry.ff_dense.get(impl)
    return kimpl.fn(x, w, b, norm=norm, interpret=_interpret(),
                    blocks=blocks)


def flash_attention(q, k, v, *, causal=True, window=None, impl="auto",
                    force_pallas=None):
    """Blockwise attention through the impl registry (same ``impl=``
    contract as ``ff_dense``; "auto" = platform default)."""
    impl = _legacy_force_pallas("flash_attention", force_pallas, impl)
    if impl == "auto":
        impl = registry.flash_attention.resolve(_platform()).name
    kimpl = registry.flash_attention.get(impl)
    return kimpl.fn(q, k, v, causal=causal, window=window,
                    interpret=_interpret())


def mamba2_ssd(xbar, dA, b, c, *, chunk=128, impl="auto",
               force_pallas=None):
    """Chunked SSD scan through the impl registry (same ``impl=``
    contract as ``ff_dense``; "auto" = platform default)."""
    impl = _legacy_force_pallas("mamba2_ssd", force_pallas, impl)
    if impl == "auto":
        impl = registry.mamba2_ssd.resolve(_platform()).name
    kimpl = registry.mamba2_ssd.get(impl)
    return kimpl.fn(xbar, dA, b, c, chunk=chunk, interpret=_interpret())
