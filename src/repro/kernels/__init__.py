"""TPU Pallas kernels for the compute hot-spots.

  ff_dense        — the FF-MLP hot loop: fused matmul -> ReLU -> goodness
                    (one pass computes the layer output AND the per-row
                    sum-of-squares the FF loss needs).
  flash_attention — blockwise online-softmax attention (GQA / causal /
                    sliding-window) for the transformer archs.
  mamba2_ssd      — chunked SSD dual-form scan (intra-chunk quadratic +
                    carried state) for Mamba-2.

Each kernel ships as <name>.py (pl.pallas_call + BlockSpec), ops.py
(jit'd dispatch wrapper), ref.py (pure-jnp oracle). On CPU the kernels
run under interpret=True; the model code calls the pure-JAX paths by
default and the kernels are validated against them in tests/.
"""
