"""Serving replica: versioned snapshot install + fixed-shape scoring.

The replica is the consumer end of the ``WeightBus``. Between request
batches it installs the next fully-assembled snapshot — stepping
through versions IN ORDER so every completed chapter produces a visible
hot-swap — and audits each install against the consistency contract:
the snapshot's version vector must be uniform (every layer at the same
chapter) and strictly newer than the installed one (monotone). Any
breach increments ``consistency_violations`` instead of installing;
the benchmark and the acceptance gate require that counter to be zero.

Scoring pads every batch to one fixed ``max_batch`` shape so the jitted
scorer (``ff_mlp.class_scores`` — the classifier-registry path over the
fused ``ops.ff_dense`` kernel) compiles exactly once; continuous
batching then never pays a retrace mid-run.
"""
from __future__ import annotations

import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ff_mlp
from repro.obs import trace as obs_trace
from repro.serve.bus import WeightBus


class Replica:
    def __init__(self, num_classes: int, *, max_batch: int,
                 eval_mode: str = "goodness", impl: str = "auto",
                 tracer=obs_trace.NOOP):
        self.num_classes = int(num_classes)
        self.max_batch = int(max_batch)
        self.eval_mode = eval_mode
        self.impl = impl
        self.tracer = tracer
        self.params: Optional[dict] = None
        self.version: int = -(2 ** 31)        # below any published version
        self.swaps: List[dict] = []           # install log (the timeline)
        self.consistency_violations = 0
        self.batches_scored = 0
        self._scorer = jax.jit(
            lambda params, x: ff_mlp.class_scores(
                params, x, self.num_classes, self.eval_mode,
                impl=self.impl))

    @property
    def ready(self) -> bool:
        return self.params is not None

    # ---- snapshot install ------------------------------------------------
    def _vector_ok(self, version: int, vec: list) -> bool:
        """The consistency contract: uniform (no half-published layer
        set) and monotone (never roll a replica backward)."""
        return (len(set(vec)) == 1 and vec[0] == version
                and version > self.version)

    def install(self, version: int, params: dict, vec: list,
                published_at: float, *, now: float = 0.0) -> bool:
        """Audit + install one snapshot; False (and a counted violation)
        if it breaches the version-vector contract."""
        t0 = self.tracer.now()
        if not self._vector_ok(version, vec):
            self.consistency_violations += 1
            if self.tracer.enabled:
                self.tracer.event("serve:violation", version=version,
                                  vec=list(vec), installed=self.version)
            return False
        self.params = params
        old = self.version
        self.version = version
        staleness = max(time.perf_counter() - published_at, 0.0)
        self.swaps.append({
            "t": now, "version": version, "from_version": old,
            "staleness_s": staleness})
        if self.tracer.enabled:
            self.tracer.add_span("serve:swap_install", t0, version=version,
                                 from_version=old, staleness_s=staleness)
        return True

    def maybe_swap(self, bus: WeightBus, *, now: float = 0.0) -> bool:
        """Install the next newer snapshot, if one is assembled."""
        rec = bus.next_snapshot(self.version)
        if rec is None:
            return False
        return self.install(rec[0], rec[1], rec[2], rec[3], now=now)

    def drain(self, bus: WeightBus, *, now: float = 0.0) -> int:
        """Install every remaining version in order (shutdown path —
        the final snapshot must be the fully-trained model)."""
        n = 0
        while self.maybe_swap(bus, now=now):
            n += 1
        return n

    # ---- scoring ---------------------------------------------------------
    def score(self, x: np.ndarray) -> np.ndarray:
        """(n, num_classes) scores for up to ``max_batch`` rows; the
        batch is zero-padded to the fixed jit shape and the padding
        sliced back off."""
        if self.params is None:
            raise RuntimeError("replica has no installed snapshot yet")
        n = x.shape[0]
        if n > self.max_batch:
            raise ValueError(f"batch of {n} exceeds max_batch="
                             f"{self.max_batch}")
        if n < self.max_batch:
            pad = np.zeros((self.max_batch - n,) + x.shape[1:], x.dtype)
            x = np.concatenate([x, pad], axis=0)
        scores = self._scorer(self.params, jnp.asarray(x))
        self.batches_scored += 1
        return np.asarray(scores[:n])

    def predict(self, x: np.ndarray) -> np.ndarray:
        return np.argmax(self.score(x), axis=1).astype(np.int32)
