"""Attention: chunked (flash-style, O(S·chunk) memory) full-sequence path
for train/prefill and a cache-based decode path. Supports GQA/MQA, causal,
sliding-window, bidirectional (encoder) and cross-attention.

The chunked path is pure JAX (double ``lax.scan`` with online softmax) so
that the 32k-sequence dry-runs lower with sane memory; the TPU-optimized
kernel lives in ``repro.kernels.flash_attention`` and is numerically
validated against this path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30

# §Perf toggles (set by launch/dryrun.py opts; read at trace time).
DEFAULT_CAUSAL_SKIP = False
PV_BF16 = False       # cast the post-softmax P matrix to bf16 for the
                      # PV matmul (f32 accumulation via MXU) — halves the
                      # largest attention buffer's traffic


def _pv(p, v):
    """P @ V with optional bf16 P (f32 accumulate)."""
    if PV_BF16:
        return jnp.einsum("bkgqs,bskd->bkgqd", p.astype(jnp.bfloat16),
                          v.astype(jnp.bfloat16),
                          preferred_element_type=jnp.float32)
    return jnp.einsum("bkgqs,bskd->bkgqd", p, v.astype(jnp.float32))


def _mask(q_pos, k_pos, causal, window):
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), jnp.bool_)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        m &= (q_pos[:, None] - k_pos[None, :]) < window
    return m


def chunked_attention(q, k, v, *, causal=True, window=None, q_offset=0,
                      k_offset=0, q_chunk=512, k_chunk=1024,
                      causal_skip=False):
    """q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd). Returns (B, Sq, H, hd).

    Online-softmax over kv chunks, scanned over q chunks: peak score
    buffer is (B, H, q_chunk, k_chunk) regardless of sequence length.

    ``causal_skip`` (a §Perf optimization, off by default): instead of
    the dense nq x nk double scan, enumerate only the VISIBLE (q, k)
    chunk pairs (causal upper triangle, window band) statically and
    scan that flat list — ~2x fewer matmuls and ~2x less chunk IO for
    causal self-attention at equal numerics.
    """
    if (causal_skip and isinstance(q_offset, int) and q_offset == 0
            and isinstance(k_offset, int) and k_offset == 0):
        return _triangle_attention(q, k, v, causal=causal, window=window,
                                   q_chunk=q_chunk, k_chunk=k_chunk)
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    q_chunk = min(q_chunk, Sq)
    k_chunk = min(k_chunk, Sk)
    if Sq % q_chunk:
        q_chunk = Sq
    if Sk % k_chunk:
        k_chunk = Sk
    nq, nk = Sq // q_chunk, Sk // k_chunk
    scale = hd ** -0.5

    # (nq, B, qc, KV, G, hd)
    qs = q.reshape(B, nq, q_chunk, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)
    ks = k.reshape(B, nk, k_chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, k_chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    q_positions = q_offset + jnp.arange(Sq, dtype=jnp.int32)
    k_positions = k_offset + jnp.arange(Sk, dtype=jnp.int32)

    def q_body(_, qi):
        qc, q_pos = qi                       # (B, qc, KV, G, hd), (qc,)
        qcf = qc.astype(jnp.float32) * scale

        def k_body(carry, ki):
            m_run, l_run, acc = carry
            kc, vc, k_pos = ki               # (B, kc, KV, hd)
            s = jnp.einsum("bqkgd,bskd->bkgqs", qcf,
                           kc.astype(jnp.float32))     # (B, KV, G, qc, kc)
            msk = _mask(q_pos, k_pos, causal, window)  # (qc, kc)
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_run, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + p.sum(axis=-1)
            pv = _pv(p, vc)
            acc = acc * corr[..., None] + pv
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, KV, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, KV, G, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            k_body, (m0, l0, a0), (ks, vs, k_positions.reshape(nk, k_chunk)))
        l = jnp.maximum(l, 1e-30)
        out = (acc / l[..., None]).astype(q.dtype)     # (B, KV, G, qc, hd)
        return None, out.transpose(0, 3, 1, 2, 4)      # (B, qc, KV, G, hd)

    _, outs = jax.lax.scan(
        q_body, None, (qs, q_positions.reshape(nq, q_chunk)))
    # (nq, B, qc, KV, G, hd) -> (B, Sq, H, hd)
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, hd)


def _triangle_attention(q, k, v, *, causal, window, q_chunk, k_chunk):
    """Visible-chunk-pair enumeration (static) + flat scan.

    Carries full (nq, ...) online-softmax tables; each step updates one
    q-chunk's row via dynamic indexing. Invisible pairs never execute.
    """
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    qc = min(q_chunk, Sq)
    kc = min(k_chunk, Sk)
    if Sq % qc:
        qc = Sq
    if Sk % kc:
        kc = Sk
    nq, nk = Sq // qc, Sk // kc
    scale = hd ** -0.5

    pairs = []
    for i in range(nq):
        q_lo, q_hi = i * qc, i * qc + qc - 1
        for j in range(nk):
            k_lo, k_hi = j * kc, j * kc + kc - 1
            if causal and k_lo > q_hi:
                continue                       # strictly above diagonal
            if window is not None and k_hi <= q_lo - window:
                continue                       # entirely below the band
            pairs.append((i, j))
    pairs_arr = jnp.asarray(pairs, jnp.int32)   # (P, 2)

    qs = q.reshape(B, nq, qc, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)
    ks = k.reshape(B, nk, kc, KV, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, kc, KV, hd).transpose(1, 0, 2, 3, 4)

    def body(carry, pair):
        m_t, l_t, acc_t = carry                 # (nq, B, KV, G, qc[, hd])
        i, j = pair[0], pair[1]
        qb = jax.lax.dynamic_index_in_dim(qs, i, 0, keepdims=False)
        kb = jax.lax.dynamic_index_in_dim(ks, j, 0, keepdims=False)
        vb = jax.lax.dynamic_index_in_dim(vs, j, 0, keepdims=False)
        qf = qb.astype(jnp.float32) * scale
        s = jnp.einsum("bqkgd,bskd->bkgqs", qf, kb.astype(jnp.float32))
        q_pos = i * qc + jnp.arange(qc, dtype=jnp.int32)
        k_pos = j * kc + jnp.arange(kc, dtype=jnp.int32)
        msk = _mask(q_pos, k_pos, causal, window)
        s = jnp.where(msk[None, None, None], s, NEG_INF)

        m_run = jax.lax.dynamic_index_in_dim(m_t, i, 0, keepdims=False)
        l_run = jax.lax.dynamic_index_in_dim(l_t, i, 0, keepdims=False)
        acc = jax.lax.dynamic_index_in_dim(acc_t, i, 0, keepdims=False)
        m_new = jnp.maximum(m_run, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + p.sum(axis=-1)
        pv = _pv(p, vb)
        acc = acc * corr[..., None] + pv
        m_t = jax.lax.dynamic_update_index_in_dim(m_t, m_new, i, 0)
        l_t = jax.lax.dynamic_update_index_in_dim(l_t, l_new, i, 0)
        acc_t = jax.lax.dynamic_update_index_in_dim(acc_t, acc, i, 0)
        return (m_t, l_t, acc_t), None

    m0 = jnp.full((nq, B, KV, G, qc), NEG_INF, jnp.float32)
    l0 = jnp.zeros((nq, B, KV, G, qc), jnp.float32)
    a0 = jnp.zeros((nq, B, KV, G, qc, hd), jnp.float32)
    (m_t, l_t, acc_t), _ = jax.lax.scan(body, (m0, l0, a0), pairs_arr)
    l_t = jnp.maximum(l_t, 1e-30)
    out = (acc_t / l_t[..., None]).astype(q.dtype)  # (nq, B, KV, G, qc, hd)
    return out.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, H, hd)


def decode_attention(q, k_cache, v_cache, k_pos, cur_pos, *, window=None):
    """One-token attention against a cache.

    q: (B, H, hd); k_cache/v_cache: (B, S, KV, hd);
    k_pos: (S,) int32 positions held in each cache slot (-1 = empty);
    cur_pos: scalar int32 — position of the query token.
    """
    B, H, hd = q.shape
    _, S, KV, _ = k_cache.shape
    G = H // KV
    qf = q.reshape(B, KV, G, hd).astype(jnp.float32) * hd ** -0.5
    s = jnp.einsum("bkgd,bskd->bkgs", qf, k_cache.astype(jnp.float32))
    valid = (k_pos >= 0) & (k_pos <= cur_pos)
    if window is not None:
        valid &= (cur_pos - k_pos) < window
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, H, hd).astype(q.dtype)
