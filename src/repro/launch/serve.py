"""Serving launcher — a thin CLI over the ``repro.api`` facade.

Default mode (``--mode ff``) runs the train-while-serve workload:
``api.serve`` trains the config on the executor while a continuous-
batching replica serves the configured traffic from live hot-swapped
weights, then prints the SLO block and the swap timeline.

  PYTHONPATH=src python -m repro.launch.serve --traffic zipf \
      --schedule all_layers --nodes 4

``--mode lm`` keeps the old transformer prefill+decode demo
(``lm_decode``):

  PYTHONPATH=src python -m repro.launch.serve --mode lm \
      --arch qwen2-0.5b --batch 4 --prompt-len 64 --gen 32

The module-level ``serve(cfg, ...)`` of earlier versions (the LM demo)
is deprecated: call ``lm_decode`` for the demo or ``repro.api.serve``
for the serving subsystem.
"""
from __future__ import annotations

import argparse
import time
import warnings

import jax
import jax.numpy as jnp

from repro import data as data_lib
from repro.configs import get_config
from repro.models import transformer


def lm_decode(cfg, *, batch, prompt_len, gen, seed=0, greedy=True):
    """Prefill a batch of prompts, then batched greedy decode against
    the KV caches — the CPU-scale transformer serving demo."""
    key = jax.random.PRNGKey(seed)
    params = transformer.init(key, cfg)
    prompts = jnp.asarray(next(iter(data_lib.lm_batches(
        cfg.vocab, batch, prompt_len - 1, 1, seed))))

    aux = None
    if cfg.enc_dec:
        aux = jax.random.normal(key, (batch, cfg.enc_seq, cfg.d_model),
                                cfg.dtype)
    elif cfg.vision_tokens:
        aux = jax.random.normal(key, (batch, cfg.vision_tokens,
                                      cfg.d_model), cfg.dtype)

    max_len = prompt_len + gen
    prefill = jax.jit(lambda p, t, a: transformer.prefill(
        p, cfg, t, aux=a, max_len=max_len, last_only=True))
    step = jax.jit(lambda p, c, t, pos: transformer.serve_step(
        p, cfg, c, t, pos))

    t0 = time.time()
    logits, caches = prefill(params, prompts, aux)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    tok = jnp.argmax(logits, axis=-1)              # (B,)
    out = [tok]
    t1 = time.time()
    for i in range(gen - 1):
        logits, caches = step(params, caches, tok, prompt_len + i)
        tok = (jnp.argmax(logits, axis=-1) if greedy
               else jax.random.categorical(
                   jax.random.fold_in(key, i), logits))
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t1
    gen_tokens = jnp.stack(out, axis=1)
    return {
        "generated": gen_tokens,
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "decode_tok_per_s": batch * (gen - 1) / max(t_decode, 1e-9),
    }


def serve(cfg, *, batch, prompt_len, gen, seed=0, greedy=True):
    """Deprecated: this was the transformer decode demo — use
    ``lm_decode`` (same signature), or ``repro.api.serve`` for the
    goodness-classifier serving subsystem."""
    warnings.warn("launch.serve.serve is deprecated; use launch.serve."
                  "lm_decode for the transformer demo or repro.api."
                  "serve for the serving subsystem",
                  DeprecationWarning, stacklevel=2)
    return lm_decode(cfg, batch=batch, prompt_len=prompt_len, gen=gen,
                     seed=seed, greedy=greedy)


def _main_ff(args):
    from repro import api
    from repro.configs.ff_mlp import FFMLPConfig
    from repro.obs import export as obs_export, trace as obs_trace

    task = data_lib.mnist_like(n_train=args.n_train, n_test=400)
    cfg = FFMLPConfig(
        layer_sizes=(task.dim,) + (args.width,) * args.layers,
        epochs=args.epochs, splits=args.splits, neg_mode="random",
        classifier="goodness", batch_size=64, seed=args.seed)
    # block_tasks=False: the point of tracing a serve run is the live
    # interleaving of training and scoring — keep the async overlap
    tracer = (obs_trace.Tracer(block_tasks=False,
                               meta={"launcher": "serve"})
              if args.trace else obs_trace.NOOP)
    res = api.serve(cfg, task, traffic=args.traffic,
                    schedule=args.schedule, num_nodes=args.nodes,
                    rate=args.rate, max_batch=args.max_batch,
                    max_wait_s=args.max_wait, queue_cap=args.queue_cap,
                    seed=args.seed, trace=tracer)
    if tracer.enabled:
        obs_export.export(tracer, args.trace, format=args.trace_format)
        print(f"trace: {tracer.span_count()} spans -> {args.trace} "
              f"({args.trace_format})")
    slo = res.slo
    print(f"train-while-serve: schedule={res.schedule} "
          f"nodes={res.num_nodes} traffic={res.traffic}")
    print(f"  train acc={res.fit.test_acc:.4f} "
          f"makespan={res.fit.makespan:.2f}s")
    print(f"  served {slo['requests']} req @ "
          f"{slo['throughput_rps']:.1f} rps  "
          f"p50={slo['latency_p50_ms']:.1f}ms "
          f"p99={slo['latency_p99_ms']:.1f}ms  "
          f"shed={slo['shed_rate']:.3f}")
    print(f"  swaps={slo['swaps']} "
          f"staleness_max={slo['staleness_max_s']:.3f}s "
          f"violations={slo['consistency_violations']}")
    for v, row in res.accuracy_by_version.items():
        print(f"    version {v:3d}: n={row['n']:5d} "
              f"acc={row['accuracy']:.3f}")
    return 1 if slo["consistency_violations"] else 0


def _main_lm(args):
    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    res = lm_decode(cfg, batch=args.batch, prompt_len=args.prompt_len,
                    gen=args.gen, seed=args.seed)
    print(f"prefill {res['prefill_s']:.2f}s  decode {res['decode_s']:.2f}s"
          f"  ({res['decode_tok_per_s']:.1f} tok/s)")
    print("first generated rows:", res["generated"][:2, :12])
    return 0


def main(argv=None):
    from repro import api
    from repro.core import pff_dag

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mode", choices=("ff", "lm"), default="ff",
                    help="ff: train-while-serve via api.serve (default);"
                         " lm: transformer prefill+decode demo")
    g = ap.add_argument_group("ff mode")
    g.add_argument("--traffic", default="uniform",
                   choices=list(api.traffic.names()))
    g.add_argument("--schedule", default="all_layers",
                   choices=list(pff_dag.SCHEDULES))
    g.add_argument("--nodes", type=int, default=4)
    g.add_argument("--rate", type=float, default=300.0)
    g.add_argument("--max-batch", type=int, default=64)
    g.add_argument("--max-wait", type=float, default=0.02)
    g.add_argument("--queue-cap", type=int, default=512)
    g.add_argument("--epochs", type=int, default=100)
    g.add_argument("--splits", type=int, default=4)
    g.add_argument("--layers", type=int, default=2)
    g.add_argument("--width", type=int, default=256)
    g.add_argument("--n-train", type=int, default=2560)
    lm = ap.add_argument_group("lm mode")
    lm.add_argument("--arch", default=None,
                    help="transformer config name (lm mode)")
    lm.add_argument("--full", action="store_true")
    lm.add_argument("--batch", type=int, default=4)
    lm.add_argument("--prompt-len", type=int, default=64)
    lm.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    from repro.obs import export as obs_export
    g.add_argument("--trace", default=None, metavar="PATH",
                   help="record an execution trace (repro.obs; "
                        "non-blocking tracer, overlap intact) and "
                        "export it here after the run")
    g.add_argument("--trace-format", default="chrome",
                   choices=list(obs_export.names()),
                   help="trace exporter (choices live from the "
                        "repro.obs exporter registry)")
    args = ap.parse_args(argv)
    if args.mode == "lm":
        if args.arch is None:
            ap.error("--mode lm requires --arch")
        return _main_lm(args)
    return _main_ff(args)


if __name__ == "__main__":
    raise SystemExit(main())
