"""Continuous batch former: the max-batch / max-wait trade-off knob.

A batch is released when it is FULL (``max_batch`` requests ready — the
throughput case) or when the head request has waited ``max_wait_s``
since arrival (the latency case: a lone request is not held hostage to
fill a batch). Everything in between is the continuous-batching
spectrum the serve benchmark sweeps.
"""
from __future__ import annotations

from typing import List

from repro.serve.queue import AdmissionQueue, Request


class Batcher:
    def __init__(self, max_batch: int, max_wait_s: float):
        if max_batch <= 0:
            raise ValueError(f"max_batch must be positive, got {max_batch}")
        if max_wait_s < 0:
            raise ValueError(f"max_wait_s must be >= 0, got {max_wait_s}")
        self.max_batch = int(max_batch)
        self.max_wait_s = float(max_wait_s)
        self.batches_formed = 0

    def form(self, queue: AdmissionQueue, now: float,
             *, flush: bool = False) -> List[Request]:
        """Release the next batch, or [] if the release condition is not
        met yet. ``now`` is on the same clock as request arrivals.
        ``flush=True`` releases whatever is queued regardless of the
        knobs (drain at shutdown)."""
        depth = len(queue)
        if depth == 0:
            return []
        if not flush and depth < self.max_batch:
            oldest = queue.oldest_arrival()
            if oldest is None or now - oldest < self.max_wait_s:
                return []
        batch = queue.take(self.max_batch)
        if batch:
            self.batches_formed += 1
        return batch
