"""Model / input-shape configuration for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig``. A config is
purely declarative; the model code in ``repro.models`` interprets it.

Layers are described by *groups*: ``(pattern, repeat)`` where ``pattern``
is a tuple of block kinds scanned ``repeat`` times with stacked params.
This keeps the lowered HLO O(pattern) instead of O(num_layers) — essential
for the 94-layer MoE / 100-layer VLM dry-runs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Block kinds understood by repro.models.blocks
#   attn        — GQA self-attention (+ optional sliding window)
#   local_attn  — windowed self-attention (recurrentgemma-style local)
#   cross_attn  — cross-attention to auxiliary embeddings (VLM / decoder)
#   mamba2      — SSD state-space block
#   rglru       — RG-LRU recurrent block
# Every block is followed by its MLP (dense or MoE) unless mlp="none".
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_ff: int
    num_shared: int = 0
    shared_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int = 0          # 0 -> d_model
    conv_width: int = 4
    window: int = 2048          # local-attn window used by hybrid attn blocks


@dataclasses.dataclass(frozen=True)
class FFConfig:
    """Forward-Forward training configuration (the paper's technique)."""
    goodness: str = "sumsq"       # sumsq | softmax (Performance-Optimized)
    theta: float = 2.0            # goodness threshold
    neg_mode: str = "random"      # adaptive | fixed | random (LM: corruption)
    peer_norm_weight: float = 0.03
    # layer-local loss is computed on RMS-normalized block outputs


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                       # dense|moe|ssm|hybrid|audio|vlm
    num_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    # layer grouping: tuple of (pattern tuple, repeat)
    groups: Tuple[Tuple[Tuple[str, ...], int], ...] = ()
    head_dim: int = 0                    # 0 -> d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    window: Optional[int] = None         # sliding-window size for attn blocks
    norm_eps: float = 1e-6
    act: str = "silu"
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    ff: FFConfig = dataclasses.field(default_factory=FFConfig)
    # encoder-decoder (audio) ---------------------------------------------
    enc_dec: bool = False
    enc_layers: int = 0
    enc_seq: int = 1024                  # stub frontend frame count
    # vlm ------------------------------------------------------------------
    vision_tokens: int = 0               # stub frontend patch count
    vision_dim: int = 0                  # embedding dim delivered by stub
    # training -------------------------------------------------------------
    dtype: str = "bfloat16"
    remat: bool = True
    source: str = ""                     # citation

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Embedding/unembedding table rows, padded to a multiple of 128
        so the vocab dim shards over any mesh axis (seamless 256206 and
        mamba2 50280 are otherwise indivisible by 16 and force GSPMD to
        replicate full-vocab logits — TBs of all-reduce at 4k batch).
        Padded ids never appear in data; unembed masks their logits."""
        return -(-self.vocab // 128) * 128

    def layers_in_groups(self) -> int:
        return sum(len(p) * r for p, r in self.groups)

    def validate(self) -> None:
        assert self.layers_in_groups() == (
            self.num_layers + (self.enc_layers if self.enc_dec else 0)
        ), (self.name, self.layers_in_groups(), self.num_layers)

    def reduced(self, d_model: int = 256, layers_hint: int = 2) -> "ModelConfig":
        """A tiny same-family variant for CPU smoke tests."""
        n_heads = max(2, min(4, self.n_heads))
        n_kv = max(1, min(self.n_kv, n_heads))
        while n_heads % n_kv:
            n_kv -= 1
        groups = _reduce_groups(self.groups, layers_hint)
        nl = sum(len(p) * r for p, r in groups)
        enc_l = 0
        if self.enc_dec:
            enc_l = nl // 2
            nl = nl - enc_l
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe, num_experts=4, top_k=2, expert_ff=2 * d_model,
                num_shared=min(self.moe.num_shared, 1),
                shared_ff=2 * d_model if self.moe.num_shared else 0)
        ssm = None
        if self.ssm is not None:
            ssm = dataclasses.replace(self.ssm, state_dim=16, head_dim=32,
                                      chunk=32)
        rglru = None
        if self.rglru is not None:
            rglru = dataclasses.replace(self.rglru, lru_width=d_model,
                                        window=32)
        return dataclasses.replace(
            self, name=self.name + "-smoke", num_layers=nl, d_model=d_model,
            n_heads=n_heads, n_kv=n_kv, d_ff=2 * d_model,
            vocab=min(self.vocab, 512), head_dim=0, groups=groups,
            window=min(self.window, 64) if self.window else None,
            moe=moe, ssm=ssm, rglru=rglru, enc_layers=enc_l,
            enc_seq=16, vision_tokens=8 if self.vision_tokens else 0,
            vision_dim=d_model if self.vision_dim else 0,
            dtype="float32", remat=False)


def _reduce_groups(groups, layers_hint):
    """Keep one pattern-unit per distinct group, repeat=1."""
    out = []
    seen = set()
    for pattern, _ in groups:
        if pattern in seen:
            continue
        seen.add(pattern)
        out.append((pattern, 1))
    if not out:
        out = [(("attn",), layers_hint)]
    return tuple(out)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

# Archs allowed to run long_500k (sub-quadratic sequence mixing).
SUBQUADRATIC = {"mamba2-780m", "recurrentgemma-2b", "h2o-danube-3-4b"}


_REGISTRY = {}


def register(cfg: ModelConfig) -> ModelConfig:
    cfg.validate()
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        load_all()
    return _REGISTRY[name]


def list_configs():
    if not _REGISTRY:
        load_all()
    return sorted(_REGISTRY)


def load_all():
    from repro.configs import (  # noqa: F401
        mamba2_780m, recurrentgemma_2b, seamless_m4t_large_v2,
        qwen3_moe_235b_a22b, tinyllama_1_1b, llama_3_2_vision_90b,
        qwen2_0_5b, qwen3_8b, h2o_danube_3_4b, deepseek_moe_16b, ff_mlp)
