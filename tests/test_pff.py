"""PFF schedule tests: training improves accuracy; the simulator respects
the task DAG; schedule properties match the paper's qualitative claims.
Training runs go through the supported surface (``repro.api.fit``)."""
import pytest

from repro import api, data as data_lib
from repro.configs.ff_mlp import FFMLPConfig
from repro.core import pff


@pytest.fixture(scope="module")
def tiny_result():
    task = data_lib.mnist_like(n_train=2560, n_test=200)
    cfg = FFMLPConfig(layer_sizes=(784, 400, 400), epochs=100, splits=5,
                      neg_mode="random", classifier="goodness",
                      batch_size=64, seed=0)
    return api.fit(cfg, task), task


def test_training_beats_chance(tiny_result):
    res, task = tiny_result
    assert res.test_acc > 0.5     # 10 classes, chance = 0.1


def test_records_cover_all_tasks(tiny_result):
    res, _ = tiny_result
    train_recs = [r for r in res.records if r.kind == "train"]
    assert len(train_recs) == res.cfg.splits * 2   # splits x layers
    assert all(r.duration > 0 for r in res.records)


@pytest.mark.parametrize("schedule,n", [("sequential", 1),
                                        ("single_layer", 2),
                                        ("all_layers", 2),
                                        ("all_layers", 4)])
def test_simulator_sanity(tiny_result, schedule, n):
    res, _ = tiny_result
    sim = pff.simulate_schedule(res.records, schedule, n)
    assert sim.makespan > 0
    assert 0.0 < sim.utilization <= 1.0 + 1e-9
    # never better than perfect linear scaling
    assert sim.speedup <= n + 1e-6
    if schedule == "sequential":
        assert abs(sim.speedup - 1.0) < 1e-6


def test_pipeline_beats_sequential_with_many_splits():
    """More chapters -> better pipeline utilization (paper's core claim)."""
    recs = []
    for c in range(20):
        for k in range(4):
            recs.append(pff.TaskRecord("train", k, c, 1.0))
    sim = pff.simulate_schedule(recs, "all_layers", 4)
    assert sim.speedup > 2.8          # paper: 3.75 at S=100, N=4
    sim_sl = pff.simulate_schedule(recs, "single_layer", 4)
    assert sim_sl.speedup > 1.5


def test_single_layer_penalised_by_forward_recompute():
    recs = [pff.TaskRecord("train", k, c, 1.0)
            for c in range(20) for k in range(4)]
    al = pff.simulate_schedule(recs, "all_layers", 4)
    sl = pff.simulate_schedule(recs, "single_layer", 4)
    assert sl.makespan >= al.makespan   # paper Table 1 ordering


def test_adaptive_neg_gen_serializes_single_layer():
    """AdaptiveNEG: the last node generates negatives for everyone in
    Single-Layer -> its stage slows, All-Layers parallelizes it."""
    recs = []
    for c in range(20):
        for k in range(4):
            recs.append(pff.TaskRecord("train", k, c, 1.0))
        recs.append(pff.TaskRecord("neg_gen", -1, c, 2.0))
    al = pff.simulate_schedule(recs, "all_layers", 4)
    sl = pff.simulate_schedule(recs, "single_layer", 4)
    assert al.speedup > sl.speedup      # paper: 2980s vs 5254s


def test_dag_dependencies_respected():
    """Rebuild start times: T(k,c) never starts before T(k-1,c) or
    T(k,c-1) finishes (weights/input deps)."""
    recs = [pff.TaskRecord("train", k, c, 1.0)
            for c in range(6) for k in range(3)]
    # simulate manually with the same assignment and check monotonicity
    sim = pff.simulate_schedule(recs, "all_layers", 3)
    assert sim.makespan >= 6 * 1.0      # >= S chapters of the last layer
    assert sim.makespan >= (6 / 3) * 3  # >= per-node busy time


def test_simulator_replays_local_head_records():
    """§4.4 perf_opt: ``local_head`` records ride the shared DAG — each
    runs on its layer's node (after its train task), lengthens the fair
    sequential baseline, and does NOT serialize the pipeline."""
    recs, base = [], []
    for c in range(8):
        for k in range(3):
            recs.append(pff.TaskRecord("train", k, c, 1.0))
            base.append(recs[-1])
            recs.append(pff.TaskRecord("local_head", k, c, 0.5))
    with_lh = pff.simulate_schedule(recs, "all_layers", 3)
    without = pff.simulate_schedule(base, "all_layers", 3)
    assert with_lh.makespan > without.makespan
    # layer-local heads keep the All-Layers pipeline parallel
    assert with_lh.speedup > 2.0


def test_federated_trains_on_shards():
    task = data_lib.mnist_like(n_train=2560, n_test=200)
    cfg = FFMLPConfig(layer_sizes=(784, 300), epochs=60, splits=4,
                      neg_mode="random", classifier="goodness",
                      batch_size=64, seed=0)
    res = api.fit(cfg, task, backend="federated", num_nodes=2)
    assert res.test_acc > 0.15
