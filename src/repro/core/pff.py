"""Pipeline Forward-Forward (PFF): the paper's distributed schedules.

The key observation the paper exploits: with splits, FF training is a DAG
of chapter-tasks T(k, c) = "train layer k for C epochs in chapter c" with
forward-only dependencies and NO backward edges — that is what
backpropagation would add, and why GPipe/PipeDream have bubbles that PFF
does not. Because the DAG (not the node assignment) fixes the
weight-update order, Sequential, Single-Layer PFF and All-Layers PFF
produce IDENTICAL weight streams — they differ only in wall-clock.

The PFF machinery is split across three modules:

  * ``repro.core.pff_dag``  — the chapter-task DAG itself (task set,
    dependency edges, per-schedule node assignments). Single source of
    truth consumed by both the simulator and the executor.
  * this module — (a) the canonical sequential trainer
    (``train_ff_mlp``), which executes the chapter schedule once, timing
    every task, and (b) an event-driven simulator
    (``simulate_schedule``) that replays those timings under each
    schedule's node assignment to obtain distributed training time,
    utilization and bubble fraction — the paper's Tables 1-3.
  * ``repro.core.pff_exec`` — the REAL executor: runs the same DAG
    concurrently across an actual ``jax.devices()`` set (one device per
    paper "node") with async dispatch and ``device_put`` hand-off, and
    reproduces this module's weight stream bit-exactly for All-Layers.
    ``benchmarks/pff_exec.py`` records its measured makespan next to
    the simulator's prediction.

Federated PFF additionally changes the data each chapter sees
(node-local shards), so it is always trained for real with per-node data
(``train_federated`` here, or the executor with schedule="federated").

AdaptiveNEG adds a per-chapter negative-regeneration task; in Single-Layer
the LAST node generates and publishes negatives (serializing), while in
All-Layers/Federated each node regenerates its own (parallel) — this
asymmetry reproduces the paper's observed Single-Layer penalty.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import data as data_lib, optim
from repro.core import ff, ff_mlp, pff_dag


# ---------------------------------------------------------------------------
# Canonical chapter-schedule trainer (times every task)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TaskRecord:
    kind: str                  # train | forward | neg_gen | head | publish
    layer: int                 # -1 for non-layer tasks
    chapter: int
    duration: float


@dataclasses.dataclass
class TrainResult:
    params: dict
    records: List[TaskRecord]
    test_acc: float
    train_acc: float
    cfg: object
    history: List[Tuple[int, float]]       # (chapter, test_acc) probes


def _make_negatives(key, cfg, params, x, y, mode, class_scores=None):
    """Returns negative-overlaid images (N, D)."""
    if mode == "adaptive" and class_scores is not None:
        neg_labels = ff.adaptive_wrong_labels(class_scores, y, key=key)
    else:
        neg_labels = ff.random_wrong_labels(key, y, cfg.num_classes)
    return ff.overlay_label(x, neg_labels, cfg.num_classes)


def train_ff_mlp(cfg, task: data_lib.ImageTask, *, probe_every=0,
                 node_data: Optional[List[np.ndarray]] = None,
                 num_nodes: int = 1, verbose=False) -> TrainResult:
    """Runs the canonical chapter schedule of the paper.

    node_data: optional list of per-node index arrays (Federated PFF) —
    chapter c uses node (c % num_nodes)'s shard.
    """
    key = jax.random.PRNGKey(cfg.seed)
    params = ff_mlp.init(key, cfg)
    opt = ff_mlp.opt_init(params)
    records: List[TaskRecord] = []
    history = []

    S = cfg.splits
    C = max(cfg.epochs // cfg.splits, 1)
    n_layers = len(params["layers"])
    x_all = jnp.asarray(task.x_train)
    y_all = jnp.asarray(task.y_train)
    perf_opt = cfg.goodness_fn == "perf_opt"
    impl = getattr(cfg, "kernel_impl", "auto")

    # Hoisted out of the chapter loop: label overlays and the layer-0
    # length-normalization are chapter-invariant (the positive overlay
    # never changes; the negative one changes only on regeneration), so
    # recomputing them every chapter x layer was pure waste.
    kneg = jax.random.fold_in(key, 999)
    if not perf_opt:
        # only the normalized forms are kept — the raw overlays would be
        # ~190 MB of dead weight each at MNIST scale
        xp0 = ff_mlp._norm(ff.overlay_label(x_all, y_all, cfg.num_classes))
        xn0 = ff_mlp._norm(_make_negatives(kneg, cfg, params, x_all, y_all,
                                           "random"))
    if perf_opt or cfg.classifier == "softmax":
        x_neutral = ff.overlay_neutral(x_all, cfg.num_classes)
        if perf_opt:
            xk0 = ff_mlp._norm(x_neutral)

    for chapter in range(S):
        if node_data is not None:
            idx = jnp.asarray(node_data[chapter % num_nodes])
        else:
            idx = None
        # learning-rate for this chapter's mini-epochs
        lrs = jnp.asarray([
            optim.cooldown_lr(cfg.lr_ff, chapter * C + e, cfg.epochs,
                              cfg.cooldown_after) for e in range(C)],
            jnp.float32)
        lrs_head = lrs * (cfg.lr_softmax / cfg.lr_ff)
        kc = jax.random.fold_in(key, chapter)

        if perf_opt:
            xk = xk0 if idx is None else xk0[idx]
            y_in = y_all if idx is None else y_all[idx]
            for k in range(n_layers):
                t0 = time.perf_counter()
                lp, lh, o, oh = ff_mlp.train_layer_chapter_perf_opt(
                    params["layers"][k], params["local_heads"][k],
                    opt["layers"][k], opt["local_heads"][k],
                    xk, y_in, lrs, jax.random.fold_in(kc, k),
                    batch=cfg.batch_size, epochs=C)
                jax.block_until_ready(lp)
                params["layers"][k] = lp
                params["local_heads"][k] = lh
                opt["layers"][k], opt["local_heads"][k] = o, oh
                if k + 1 < n_layers:
                    xk = ff_mlp._norm(ff_mlp.layer_apply(lp, xk))
                records.append(TaskRecord(
                    "train", k, chapter, time.perf_counter() - t0))
        else:
            # xp/xn carry the normalized inputs of the current layer
            xp = xp0 if idx is None else xp0[idx]
            xn = xn0 if idx is None else xn0[idx]
            for k in range(n_layers):
                t0 = time.perf_counter()
                lp, o = ff_mlp.train_layer_chapter(
                    params["layers"][k], opt["layers"][k], xp, xn, lrs,
                    jax.random.fold_in(kc, k), batch=cfg.batch_size,
                    epochs=C, theta=cfg.theta, peer_w=cfg.peer_w,
                    impl=impl)
                jax.block_until_ready(lp)
                params["layers"][k] = lp
                opt["layers"][k] = o
                # propagate data through the freshly-trained layer
                if k + 1 < n_layers:
                    xp = ff_mlp._norm(ff_mlp.layer_apply(lp, xp))
                    xn = ff_mlp._norm(ff_mlp.layer_apply(lp, xn))
                records.append(TaskRecord(
                    "train", k, chapter, time.perf_counter() - t0))

        # softmax head (trained alongside, layer-local — paper §3)
        if cfg.classifier == "softmax":
            t0 = time.perf_counter()
            xn_all = x_neutral if idx is None else x_neutral[idx]
            feats = ff_mlp.softmax_feats(params["layers"], xn_all)
            params["head"], opt["head"] = ff_mlp.train_head_chapter(
                params["head"], opt["head"], feats,
                y_all if idx is None else y_all[idx],
                lrs_head, jax.random.fold_in(kc, 77),
                batch=cfg.batch_size, epochs=C)
            jax.block_until_ready(params["head"]["w"])
            records.append(TaskRecord(
                "head", n_layers, chapter, time.perf_counter() - t0))

        # negative regeneration (UpdateXNEG)
        if not perf_opt and cfg.neg_mode in ("adaptive", "random"):
            t0 = time.perf_counter()
            scores = None
            if cfg.neg_mode == "adaptive":
                scores = _class_scores_chunked(params, x_all, cfg)
            xn0 = ff_mlp._norm(_make_negatives(
                jax.random.fold_in(kneg, chapter), cfg, params,
                x_all, y_all, cfg.neg_mode, scores))
            jax.block_until_ready(xn0)
            records.append(TaskRecord(
                "neg_gen", -1, chapter, time.perf_counter() - t0))

        if probe_every and (chapter + 1) % probe_every == 0:
            acc = ff_mlp.accuracy(params, task.x_test, task.y_test,
                                  cfg.num_classes, cfg.classifier,
                                  impl=impl)
            history.append((chapter + 1, acc))
            if verbose:
                print(f"  chapter {chapter + 1}/{S}: test acc {acc:.4f}")

    mode = "perf_opt_all" if perf_opt else cfg.classifier
    test_acc = ff_mlp.accuracy(params, task.x_test, task.y_test,
                               cfg.num_classes, mode, impl=impl)
    train_acc = ff_mlp.accuracy(params, task.x_train[:2000],
                                task.y_train[:2000], cfg.num_classes, mode,
                                impl=impl)
    return TrainResult(params, records, test_acc, train_acc, cfg, history)


def _class_scores_chunked(params, x, cfg, chunk=2000):
    impl = getattr(cfg, "kernel_impl", "auto")
    outs = []
    for i in range(0, x.shape[0], chunk):
        outs.append(ff_mlp.goodness_class_scores(
            params, x[i:i + chunk], cfg.num_classes, impl=impl))
    return jnp.concatenate(outs, axis=0)


# ---------------------------------------------------------------------------
# Event-driven schedule simulator
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SimResult:
    schedule: str
    num_nodes: int
    makespan: float
    sequential_time: float
    speedup: float
    utilization: float
    bubble_fraction: float
    node_busy: List[float]


def task_durations(records: List[TaskRecord], *, reducer=np.median):
    """Duration per (kind, layer), reduced with ``reducer``.

    The default ``np.median`` is robust to jit-compile outliers (the
    first occurrence of every task shape pays compilation). The reducer
    is exposed because these durations are what ``simulate_schedule``
    replays — and what the real executor (``repro.core.pff_exec``) is
    validated against in ``benchmarks/pff_exec.py``.
    """
    acc: Dict[Tuple[str, int], List[float]] = {}
    for r in records:
        acc.setdefault((r.kind, r.layer), []).append(r.duration)
    return {k: float(reducer(v)) for k, v in acc.items()}


def simulate_schedule(records: List[TaskRecord], schedule: str,
                      num_nodes: int, *, comm_time: float = 0.0,
                      forward_frac: float = 0.18,
                      reducer=np.median) -> SimResult:
    """Replays the ``pff_dag`` task DAG under a node assignment.

    forward_frac: cost of re-running the forward pass of ONE layer over
    the train set, as a fraction of one train-task (used by Single-Layer,
    Algorithm 1 lines 3-5; measured ratio fwd/train ≈ C * this).

    Negatives are used at whatever freshness is available
    ("UpdateXNEG(publish=False)", regenerated per node): they do NOT
    gate the next chapter's start (``strict_neg=False`` in the DAG) —
    their cost appears only as node busy time. This matches the paper's
    All-Layers AdaptiveNEG behaviour; the executor's bit-exact mode
    gates instead.
    """
    dur = task_durations(records, reducer=reducer)
    layers = sorted({r.layer for r in records if r.kind == "train"})
    chapters = sorted({r.chapter for r in records if r.kind == "train"})
    L, S = len(layers), len(chapters)
    has_head = any(k == "head" for k, _ in dur)
    has_neg = any(k == "neg_gen" for k, _ in dur)

    t_train = {k: dur[("train", k)] for k in layers}
    t_head = dur.get(("head", L), 0.0)
    t_neg = dur.get(("neg_gen", -1), 0.0)
    # fair sequential baseline: same median task costs, one node
    seq_total = S * (sum(t_train.values()) + (t_head if has_head else 0.0)
                     + (t_neg if has_neg else 0.0))

    def owner(task: pff_dag.Task) -> int:
        if task.kind == "head":
            return pff_dag.head_node_of(schedule, num_nodes, n_layers=L,
                                        chapter=task.chapter)
        if task.kind == "neg_gen":
            return pff_dag.neg_node_of(schedule, num_nodes,
                                       chapter=task.chapter)
        return pff_dag.node_of(schedule, num_nodes, layer=task.layer,
                               chapter=task.chapter)

    def cost(task: pff_dag.Task) -> float:
        if task.kind == "head":
            return t_head
        if task.kind == "neg_gen":
            return t_neg
        extra = 0.0
        if schedule == "single_layer" and task.layer > 0:
            # re-forward layers < k over the train set (Algorithm 1)
            extra = forward_frac * sum(t_train[j]
                                       for j in range(task.layer))
        return extra + t_train[task.layer]

    # ---- event simulation over the shared DAG ------------------------------
    node_free = [0.0] * num_nodes
    node_busy = [0.0] * num_nodes
    done: Dict[pff_dag.Task, float] = {}

    for task in pff_dag.build_tasks(L, S, has_head=has_head,
                                    has_neg=has_neg):
        n = owner(task)
        start = node_free[n]
        for dep in pff_dag.deps(task, L, has_head=has_head,
                                has_neg=has_neg):
            start = max(start, done[dep] +
                        (comm_time if owner(dep) != n else 0.0))
        t = cost(task)
        end = start + t
        node_free[n] = end
        node_busy[n] += t
        done[task] = end

    makespan = max(node_free)
    speedup = seq_total / makespan if makespan > 0 else 1.0
    util = sum(node_busy) / (num_nodes * makespan) if makespan else 1.0
    return SimResult(schedule, num_nodes, makespan, seq_total, speedup,
                     util, 1.0 - util, node_busy)


# ---------------------------------------------------------------------------
# Federated PFF (actually trains on node-local shards)
# ---------------------------------------------------------------------------

def train_federated(cfg, task: data_lib.ImageTask, num_nodes: int,
                    **kw) -> TrainResult:
    rng = np.random.default_rng(cfg.seed)
    order = rng.permutation(len(task.x_train))
    shards = [order[i::num_nodes] for i in range(num_nodes)]
    return train_ff_mlp(cfg, task, node_data=shards, num_nodes=num_nodes,
                        **kw)
