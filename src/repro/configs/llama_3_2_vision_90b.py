"""llama-3.2-vision-90b — cross-attention image layers
[hf:meta-llama/Llama-3.2-11B-Vision, scaled to 90B per assignment].

100 layers = 20 x (4 self-attn + 1 cross-attn). d_model=8192, 64 heads
(GQA kv=8, head_dim=128), d_ff=28672, vocab=128256. The vision frontend
(ViT encoder + projector) is a stub: input_specs() supplies projected
patch embeddings (batch, vision_tokens, d_model).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama-3.2-vision-90b",
    arch_type="vlm",
    num_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv=8,
    head_dim=128,
    d_ff=28672,
    vocab=128256,
    rope_theta=500000.0,
    groups=((("attn", "attn", "attn", "attn", "cross_attn"), 20),),
    vision_tokens=1600,
    vision_dim=8192,
    source="hf:meta-llama/Llama-3.2-11B-Vision (90B scale per assignment)",
))
