"""``repro.obs`` — tracing, metrics & critical-path analysis.

The measurement substrate under every wall-clock claim in the repo:

* ``obs.trace`` — thread-safe ``Tracer`` (spans / events / counters on
  one monotonic clock domain) with a zero-overhead ``NOOP`` default.
* ``obs.export`` — exporter registry (``register_exporter``) with
  Chrome/Perfetto ``trace.json`` and JSONL builtins.
* ``obs.analyze`` — critical path over ``pff_dag.deps``, per-node
  busy/idle, hand-off on/off-critical-path attribution, makespan
  decomposition.

Enable via ``api.fit(..., trace=True)`` / ``api.serve(...,
trace=True)`` (or pass a ``Tracer``), read the handle back from
``FitResult.trace`` / ``ServeResult.trace``, then
``obs.export.export(result.trace, "trace.json")`` and load it in
Perfetto, or ``obs.analyze.analyze(result.trace)``.

``export``/``analyze`` are lazy attributes (PEP 562): importing
``repro.obs`` (which ``checkpoint.py`` does for the ``NOOP`` tracer)
stays as cheap as ``obs.trace`` itself — no registry, no ``pff_dag``,
no jax — until a consumer actually touches them.
"""
import importlib

from repro.obs.trace import NOOP, Tracer, as_tracer          # noqa: F401

_SUBMODULES = ("trace", "export", "analyze")


def __getattr__(name):
    if name in _SUBMODULES:
        return importlib.import_module(f"repro.obs.{name}")
    raise AttributeError(f"module 'repro.obs' has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_SUBMODULES))
