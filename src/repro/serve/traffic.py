"""Deterministic open-loop traffic generators for the serving loop.

A traffic strategy shapes WHEN requests arrive and WHICH class each one
asks about; the payload pixels come from a ``data.Source``. Strategies
live in a ``strategies.Registry`` — the serving loop does a registry
lookup, never a string-``if`` — and the CLI sources its ``--traffic``
choices live from ``names()``, exactly like ``--schedule`` /
``--goodness-fn`` already do.

Strategy signature (all builtins, and anything registered via
``repro.api.register_traffic``):

    fn(rng, n, rate, num_classes) -> (gaps, classes)

``gaps`` is an (n,) float array of inter-arrival times in seconds at a
nominal mean rate of ``rate`` requests/second; ``classes`` is an (n,)
int32 array of requested class labels. Both must be pure functions of
the rng — ``RequestStream`` derives one rng per (seed, chunk) with
``data.py``'s seeding idiom, so a stream replays bit-identically from
its seed alone (the deterministic-replay test relies on it).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from repro import data as data_lib
from repro.core import strategies
from repro.serve.queue import Request


@dataclasses.dataclass(frozen=True)
class TrafficStrategy:
    """One arrival/class-mix shape. ``fn(rng, n, rate, num_classes)``
    returns ``(gaps, classes)`` as documented in the module docstring."""
    name: str
    fn: Callable


traffic = strategies.Registry("traffic")


def register_traffic(name, fn, *, overwrite=False):
    """Register a traffic shape (``repro.api.register_traffic``)."""
    return traffic.register(name, TrafficStrategy(name=name, fn=fn),
                            overwrite=overwrite)


# ---------------------------------------------------------------------------
# Builtins
# ---------------------------------------------------------------------------

def _uniform(rng, n, rate, num_classes):
    """Steady clock-tick arrivals, uniform class mix — the baseline."""
    gaps = np.full(n, 1.0 / rate)
    classes = rng.integers(0, num_classes, size=n).astype(np.int32)
    return gaps, classes


def _zipf(rng, n, rate, num_classes, *, alpha=1.1):
    """Poisson arrivals with a Zipf-skewed class mix: a few head classes
    dominate (the realistic serving distribution). The class->rank map
    is itself drawn from the rng, so different seeds skew different
    classes."""
    gaps = rng.exponential(1.0 / rate, size=n)
    p = 1.0 / np.arange(1, num_classes + 1) ** alpha
    p /= p.sum()
    ranks = rng.permutation(num_classes)
    classes = ranks[rng.choice(num_classes, size=n, p=p)].astype(np.int32)
    return gaps, classes


def _bursty(rng, n, rate, num_classes, *, burst=8.0, duty=0.25):
    """On/off bursts: a fraction ``duty`` of requests arrive in bursts
    at ``burst``x the nominal rate, the rest idle at the matching slower
    rate (mean rate stays ~``rate``) — the admission-control stressor."""
    idle_rate = rate * (1.0 - duty) / max(1.0 - duty / burst, 1e-9)
    in_burst = rng.random(n) < duty
    gaps = np.where(in_burst,
                    rng.exponential(1.0 / (rate * burst), size=n),
                    rng.exponential(1.0 / idle_rate, size=n))
    classes = rng.integers(0, num_classes, size=n).astype(np.int32)
    return gaps, classes


register_traffic("uniform", _uniform)
register_traffic("zipf", _zipf)
register_traffic("bursty", _bursty)


# ---------------------------------------------------------------------------
# Request stream: traffic shape x payload source -> Request sequence
# ---------------------------------------------------------------------------

class RequestStream:
    """Lazy, deterministic, unbounded request sequence.

    Requests are generated in chunks; chunk ``c`` uses an rng derived
    from ``(seed, "traffic", c)`` and a payload pool sampled from the
    source at ``(split="serve", seed=seed * 100003 + c)`` — the same
    per-(seed, step) idiom as ``data.lm_batches``. Each request's
    payload is drawn from the pool's examples of its requested class
    (so a zipf class skew skews the actual scored pixels), falling back
    to any pooled example for classes the pool missed.

    ``take(n)`` yields the next ``n`` ``(arrival_time, Request)`` pairs
    with arrival times accumulated from the gaps — an open-loop arrival
    process the serve loop replays against the wall clock.
    """

    CHUNK = 256

    def __init__(self, source: data_lib.Source, strategy: TrafficStrategy,
                 *, rate: float, num_classes: Optional[int] = None,
                 seed: int = 0):
        if rate <= 0:
            raise ValueError(f"rate must be positive, got {rate}")
        self.source = source
        self.strategy = strategy
        self.rate = float(rate)
        self.num_classes = (int(num_classes) if num_classes is not None
                            else int(source.num_classes))
        self.seed = int(seed)
        self._chunk_i = 0
        self._pending = []          # reversed buffer of (t_arrival, Request)
        self._t = 0.0               # arrival clock (seconds since start)
        self._next_id = 0

    def _refill(self):
        c = self._chunk_i
        self._chunk_i += 1
        rng = np.random.default_rng([self.seed, 0x7AFF1C, c])
        gaps, classes = self.strategy.fn(rng, self.CHUNK, self.rate,
                                         self.num_classes)
        x, y = self.source.sample("serve", self.CHUNK * 2,
                                  seed=self.seed * 100003 + c)
        by_class = {k: list(np.flatnonzero(y == k)) for k in set(y.tolist())}
        out = []
        for gap, cls in zip(gaps, classes):
            pool = by_class.get(int(cls))
            if pool:
                j = pool[rng.integers(0, len(pool))]
            else:                       # pool missed this class entirely
                j = int(rng.integers(0, len(y)))
            self._t += float(gap)
            out.append((self._t, Request(id=self._next_id, x=x[j],
                                         label=int(y[j]),
                                         t_arrival=self._t)))
            self._next_id += 1
        self._pending = out[::-1]

    def take(self, n: int):
        """Next ``n`` (arrival_time, Request) pairs, arrival times
        strictly accumulating across calls."""
        out = []
        while len(out) < n:
            if not self._pending:
                self._refill()
            out.append(self._pending.pop())
        return out
