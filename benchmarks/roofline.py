"""Roofline table builder — reads the dry-run JSONs and prints/saves the
per-(arch x shape x mesh) three-term roofline analysis (deliverable g)."""
from __future__ import annotations

import json
import os

NOTE = {
    "compute": "more chips / higher MXU occupancy moves this",
    "memory": "fusion + bf16 activations cut HBM traffic",
    "collective": "resharding or larger per-device batch cuts ICI bytes",
}


def load_records(dirpath="experiments/dryrun"):
    recs = []
    if not os.path.isdir(dirpath):
        return recs
    for fn in sorted(os.listdir(dirpath)):
        if fn.endswith(".json"):
            with open(os.path.join(dirpath, fn)) as f:
                recs.append(json.load(f))
    return recs


def fmt_row(r):
    terms = {"compute": r["compute_term_s"], "memory": r["memory_term_s"],
             "collective": r["collective_term_s"]}
    dom = max(terms, key=terms.get)
    util = r.get("flops_utilization", 0.0)
    return (f"| {r['arch']:24s} | {r['shape']:11s} "
            f"| {'2x16x16' if r['multi_pod'] else '16x16':7s} "
            f"| {terms['compute']:9.4f} | {terms['memory']:9.4f} "
            f"| {terms['collective']:10.4f} | {dom:10s} | {util:5.2f} |")


def print_table(recs, multi_pod=None):
    print("| arch | shape | mesh | compute_s | memory_s | "
          "collective_s | bottleneck | MF/HF |")
    print("|---|---|---|---|---|---|---|---|")
    for r in recs:
        if multi_pod is not None and r["multi_pod"] != multi_pod:
            continue
        print(fmt_row(r))


def main():
    recs = load_records()
    if not recs:
        print("no dry-run records found — run repro.launch.dryrun first")
        return
    n1 = sum(1 for r in recs if not r["multi_pod"])
    n2 = sum(1 for r in recs if r["multi_pod"])
    print(f"# Roofline ({n1} single-pod + {n2} multi-pod records)\n")
    print("## Single-pod (16x16 = 256 chips)")
    print_table(recs, multi_pod=False)
    if n2:
        print("\n## Multi-pod (2x16x16 = 512 chips)")
        print_table(recs, multi_pod=True)
    # bottleneck census
    census = {}
    for r in recs:
        if r["multi_pod"]:
            continue
        census[r["bottleneck"]] = census.get(r["bottleneck"], 0) + 1
    print("\nbottleneck census (single-pod):", census)


if __name__ == "__main__":
    main()
