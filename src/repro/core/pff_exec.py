"""Real multi-device PFF executor: the paper's schedules on actual devices.

Where ``repro.core.pff`` times the canonical chapter schedule once and
REPLAYS the timings through an event-driven simulator, this module RUNS
the Single-Layer, All-Layers and Federated schedules concurrently across
an actual ``jax.devices()`` set — one device per paper "node"
(``launch.mesh.pff_node_devices``; on CI/CPU export
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` before importing
jax). The chapter-task DAG and the per-schedule node assignments come
from ``repro.core.pff_dag`` — the same module the simulator replays.

Execution model: the per-schedule drivers dispatch tasks in the DAG's
canonical topological order (the same order ``pff_dag.build_tasks``
lists; node assignments come from ``pff_dag.node_of`` & co — the
dependency EDGES are realized implicitly as JAX data dependencies, which
``tests/test_pff_exec.py``'s ``test_dag_topological_order`` plus the
bit-exactness oracle keep honest against the DAG module) and never
block. Every task's inputs are ``jax.device_put`` onto
its owning node (activation/weight hand-off along the DAG edges), the
jitted chapter trainers (``ff_mlp.train_layer_chapter`` & co — the fused
Pallas ``ff_dense`` hot loop, with donated param/opt buffers) are
dispatched asynchronously, and JAX's async runtime overlaps nodes: node
i crunches chapter c while node i+1 already trains layer 0 of chapter
c+1. Makespan is wall-clock from first dispatch to the last weight
buffer becoming ready.

Bit-exactness: the DAG fixes the weight-update order, so the executor
reuses the EXACT eager/jitted call sequence of the sequential trainer
per task — same keys, same learning-rate arrays, same kernel path — and
therefore reproduces ``pff.train_ff_mlp``'s weight stream bit-exactly
for All-Layers (and Federated vs ``pff.train_federated``). That is the
correctness oracle enforced by ``tests/test_pff_exec.py``. AdaptiveNEG
negatives are regenerated with "publish" semantics (the DAG's
``strict_neg`` gating: chapter c+1 trains on negatives from the full
chapter-c model), which is exactly what the sequential trainer does;
RandomNEG negatives depend only on the PRNG key, so each node
regenerates its own locally — parallel, and still bit-exact.

Double-buffered hand-off: with ``overlap=True`` (the default) every
cross-node ``device_put`` along a DAG edge is issued the moment its
producing task has been DISPATCHED, not when its consuming task needs
the data — per-(tree, node) transfer slots (``_Handoff``) so the next
chapter's weights/negatives stream onto their destination node while
the current chapter's compute is still in flight. The prefetch targets
come from ``pff_dag.handoff_targets`` / ``chapter_train_nodes`` — the
same DAG edges the dispatch order walks — and every slot is tagged with
the producing chapter (version): a consumer takes the prefetched copy
only when the version matches the state it would have pulled on demand,
so the overlapped weight stream is the bit-exact SAME weight stream
(``device_put`` moves bits, the version gate proves they are the right
ones; the on/off A-B case in ``tests/test_pff_exec.py`` enforces it).
``overlap=False`` restores the serialize-on-demand hand-off for A/B
measurement.

``benchmarks/pff_exec.py`` records this executor's measured makespan
next to the simulator's prediction (``BENCH_pff_exec.json``), with
overlap on and off, plus the hand-off transfer counts.

All strategy variation (negatives / goodness / classifier) comes from
the ``repro.core.strategies`` registries — the same objects the
sequential trainer consumes — including the Performance-Optimized
goodness path (paper §4.4): its per-layer local-head task is a
per-layer dependent of the train task in the DAG
(``pff_dag.build_tasks(has_local_heads=True)``), owned by the same
node, and the executor dispatches it FUSED with its train task (the
§4.4 objective is one two-layer-deep backprop call), which preserves
the DAG order and the bit-exactness oracle.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import data as data_lib, optim
from repro.core import ff, ff_mlp, pff, pff_dag, strategies
from repro.launch import mesh as mesh_lib


@dataclasses.dataclass
class ExecResult:
    params: dict
    schedule: str
    num_nodes: int
    makespan: float                        # seconds, first dispatch -> ready
    test_acc: float
    records: Optional[List[pff.TaskRecord]]  # per-task durations (profile)
    node_busy: Optional[List[float]]         # per-node busy seconds (profile)
    handoff: Optional[dict] = None           # transfer-slot counters


class _Handoff:
    """Double-buffered transfer slots for the DAG hand-off.

    ``prefetch`` enqueues an async ``device_put`` of a pytree onto its
    future consumer's device and parks it under ``(name, node)`` tagged
    with the producing chapter. ``take`` returns the parked copy iff the
    version matches what the consumer would have pulled on demand —
    otherwise (or with overlap disabled) it falls back to a synchronous-
    path ``device_put`` exactly like the pre-overlap executor. Slots
    whose trees will be DONATED by the consuming jit are popped on hit
    (``pop=True``) so an invalidated buffer can never be re-served;
    params-only slots stay parked so several same-chapter consumers on
    one node share a single transfer.

    Counters (the dispatch-count measurement in ``BENCH_pff_exec.json``):
    ``prefetch_issued``/``prefetch_hits`` and the fallback pulls, split
    into ``pulls_cross`` (a real inter-node transfer on the consumer's
    critical path — what double-buffering exists to hide) vs
    ``pulls_local`` (same-device no-ops).
    """

    def __init__(self, devices, enabled: bool):
        self.devices = devices
        self.enabled = enabled
        self.slots: Dict[tuple, tuple] = {}
        self.stats = {"prefetch_issued": 0, "prefetch_hits": 0,
                      "pulls_cross": 0, "pulls_local": 0}

    def prefetch(self, name, node: int, version: int, tree):
        if not self.enabled:
            return
        self.slots[(name, node)] = (
            version, jax.device_put(tree, self.devices[node]))
        self.stats["prefetch_issued"] += 1

    def _on_device(self, tree, dev) -> bool:
        leaves = jax.tree_util.tree_leaves(tree)
        try:
            return bool(leaves) and leaves[0].devices() == {dev}
        except AttributeError:                      # non-committed leaf
            return False

    def take(self, name, node: int, version: int, tree, *,
             pop: bool = False):
        slot = self.slots.get((name, node))
        if slot is not None and slot[0] == version:
            if pop:
                del self.slots[(name, node)]
            self.stats["prefetch_hits"] += 1
            return slot[1]
        dev = self.devices[node]
        self.stats["pulls_local" if self._on_device(tree, dev)
                   else "pulls_cross"] += 1
        return jax.device_put(tree, dev)


class PFFExecutor:
    """Runs one PFF schedule for real on ``num_nodes`` devices.

    ``run()`` re-initializes params from ``cfg.seed`` every call, so
    calling it twice and timing the second run measures a warm cache
    (all per-device executables compiled) — what the benchmark does.
    """

    def __init__(self, cfg, task: data_lib.ImageTask, schedule: str,
                 num_nodes: int, *, devices=None, overlap: bool = True):
        if schedule not in pff_dag.SCHEDULES:
            raise ValueError(f"unknown schedule {schedule!r}; expected "
                             f"one of {pff_dag.SCHEDULES}")
        if schedule == "sequential" and num_nodes != 1:
            raise ValueError("sequential means num_nodes=1")
        self.cfg = cfg
        self.task = task
        self.schedule = schedule
        self.num_nodes = num_nodes
        self.overlap = overlap
        self.devices = (list(devices)[:num_nodes] if devices is not None
                        else mesh_lib.pff_node_devices(num_nodes))
        self.n_layers = len(cfg.layer_sizes) - 1
        self.C = max(cfg.epochs // cfg.splits, 1)
        self.impl = ff_mlp.kernel_impl(cfg)
        self.good = strategies.goodness.get(cfg.goodness_fn)
        self.neg = strategies.negatives.get(cfg.neg_mode)
        self.cls = strategies.classifier.get(cfg.classifier)
        self.has_head = self.cls.trains_head
        self.has_neg = self.good.uses_negatives and self.neg.regenerates
        self._setup_constants()

    # ---- per-device constants (replicated once, before any timing) -------
    def _setup_constants(self):
        cfg, task = self.cfg, self.task
        key = jax.random.PRNGKey(cfg.seed)
        self.key = key
        self.kneg = jax.random.fold_in(key, 999)
        shards = None
        if self.schedule == "federated":
            # same shard construction as the sequential federated
            # trainer: chapter c uses shard c % N — which IS node
            # c % N's own shard, so training data never crosses a node
            # boundary.
            shards = pff.federated_shards(cfg, task, self.num_nodes)
        self._const: Dict[int, dict] = {}
        for node, dev in enumerate(self.devices):
            x_d = jax.device_put(task.x_train, dev)
            y_d = jax.device_put(task.y_train, dev)
            c = {"x": x_d, "y": y_d,
                 "idx": (jax.device_put(shards[node], dev)
                         if shards is not None else None)}
            if self.good.uses_negatives:
                c["xp0"] = ff_mlp._norm(ff.overlay_label(
                    x_d, y_d, cfg.num_classes))
                c["xn0_init"] = ff_mlp._norm(self.neg.fn(
                    self.kneg, cfg, None, x_d, y_d, None))
            else:
                c["xk0"] = ff_mlp._norm(ff.overlay_neutral(
                    x_d, cfg.num_classes))
            if self.has_head:
                c["x_neutral"] = ff.overlay_neutral(x_d, cfg.num_classes)
            self._const[node] = c
        jax.block_until_ready([v for c in self._const.values()
                               for v in c.values() if v is not None])

    # ---- helpers ---------------------------------------------------------
    def _lrs(self, chapter):
        cfg, C = self.cfg, self.C
        lrs = jnp.asarray([
            optim.cooldown_lr(cfg.lr_ff, chapter * C + e, cfg.epochs,
                              cfg.cooldown_after) for e in range(C)],
            jnp.float32)
        return lrs, lrs * (cfg.lr_softmax / cfg.lr_ff)

    def _pull(self, tree, node):
        """Async hand-off of a param/opt pytree onto ``node``'s device."""
        return jax.device_put(tree, self.devices[node])

    def _layer_params(self, k, node):
        """Layer k's current params resident on ``node`` — prefetched by
        the producing train task when the DAG says this node consumes
        them, on-demand ``device_put`` otherwise."""
        return self._handoff.take(("params", k), node, self._ver[k],
                                  self._states[k][0])

    def _prefetch_state(self, k, chapter, state):
        """Publish train(k, chapter)'s output toward its DAG consumers
        while the producing node is still crunching (double-buffering)."""
        nxt, param_nodes = pff_dag.handoff_targets(
            self.schedule, self.num_nodes, n_layers=self.n_layers,
            splits=self.cfg.splits, layer=k, chapter=chapter,
            has_head=self.has_head,
            has_neg=self.has_neg and self.neg.needs_scores)
        if nxt is not None:
            self._handoff.prefetch(("state", k), nxt, chapter, state)
        for node in param_nodes:
            self._handoff.prefetch(("params", k), node, chapter, state[0])

    def _fwd(self, lp, x):
        """One layer forward + Hinton length-norm — the inter-layer
        hand-off. ``ff_mlp.fwd_norm`` is the exact call the sequential
        trainer makes (bit-exactness depends on it); the norm divide
        runs in the ``ff_dense`` kernel epilogue."""
        return ff_mlp.fwd_norm(lp, x, impl=self.impl)

    def _xn0_for(self, chapter, node):
        """The (full-size, normalized) negatives the sequential trainer
        would use for this chapter, resident on ``node``."""
        const = self._const[node]
        if not self.has_neg or chapter == 0:
            return const["xn0_init"]
        if not self.neg.needs_scores:
            # key-only — each node regenerates its own copy locally
            # (the paper's parallel per-node UpdateXNEG), bit-identical
            # to the sequential trainer's stream by PRNG determinism.
            return ff_mlp._norm(self.neg.fn(
                jax.random.fold_in(self.kneg, chapter - 1), self.cfg,
                None, const["x"], const["y"], None))
        # score-needing (AdaptiveNEG): published by chapter-(c-1)'s
        # neg_gen task (and prefetched to this node while chapter c-1
        # was still computing, when overlap is on)
        src_chapter, xn0 = self._neg
        assert src_chapter == chapter - 1, (src_chapter, chapter)
        return self._handoff.take(("neg",), node, src_chapter, xn0)

    def _chapter_inputs(self, chapter, node):
        """(acts, extras) exactly as the sequential trainer builds them:
        activations flow layer-to-layer, extras (labels) do not."""
        const = self._const[node]
        idx = const["idx"]
        if self.good.uses_negatives:
            xn0 = self._xn0_for(chapter, node)
            return ((const["xp0"] if idx is None else const["xp0"][idx],
                     xn0 if idx is None else xn0[idx]), ())
        return ((const["xk0"] if idx is None else const["xk0"][idx],),
                (const["y"] if idx is None else const["y"][idx],))

    def _maybe_record(self, profile, node, kind, layer, chapter, t0, out):
        if not profile:
            return
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        self._records.append(pff.TaskRecord(kind, layer, chapter, dt))
        self._busy[node] += dt

    # ---- per-task bodies (each mirrors the sequential trainer) -----------
    def _train_task(self, k, chapter, node, acts, extras, lrs, kc, profile):
        """One chapter-train task via the goodness strategy. For
        Performance-Optimized goodness this call carries the layer's
        local_head task fused in (see module docstring); it records as
        ONE train task — exactly like the sequential trainer's timing.
        The incoming state was prefetched onto ``node`` while the
        previous chapter computed (popped: the jit donates its buffers);
        the outgoing state is immediately published toward its DAG
        consumers."""
        t0 = time.perf_counter()
        state = self._handoff.take(("state", k), node, self._ver[k],
                                   self._states[k], pop=True)
        state = self.good.train_chapter(
            state, acts, extras, lrs, jax.random.fold_in(kc, k),
            cfg=self.cfg, epochs=self.C)
        self._states[k] = state
        self._ver[k] = chapter
        self._prefetch_state(k, chapter, state)
        self._maybe_record(profile, node, "train", k, chapter, t0,
                           state[0])
        return state[0]

    def _head_task(self, chapter, node, idx, lrs_head, kc, profile):
        const = self._const[node]
        t0 = time.perf_counter()
        xn_all = (const["x_neutral"] if idx is None
                  else const["x_neutral"][idx])
        # pull every layer onto the head node (no-op when already there,
        # e.g. all_layers; prefetched hand-off for single_layer)
        feats = ff_mlp.softmax_feats(
            [self._layer_params(k, node)
             for k in range(self.n_layers)], xn_all, impl=self.impl)
        head, op = self._handoff.take(("head",), node, self._head_ver,
                                      self._head, pop=True)
        head, op = ff_mlp.train_head_chapter(
            head, op, feats, const["y"] if idx is None else const["y"][idx],
            lrs_head, jax.random.fold_in(kc, 77),
            batch=self.cfg.batch_size, epochs=self.C)
        self._head = (head, op)
        self._head_ver = chapter
        if chapter + 1 < self.cfg.splits:
            nxt = pff_dag.head_node_of(self.schedule, self.num_nodes,
                                       n_layers=self.n_layers,
                                       chapter=chapter + 1)
            if nxt != node:
                self._handoff.prefetch(("head",), nxt, chapter,
                                       (head, op))
        self._maybe_record(profile, node, "head", self.n_layers, chapter,
                           t0, head["w"])

    def _neg_task(self, chapter, node, profile):
        """Score-needing (AdaptiveNEG) regeneration from the full
        chapter-c model, published for the next chapter
        ("UpdateXNEG(publish=True)" — the DAG's strict_neg gating,
        matching the sequential trainer)."""
        const = self._const[node]
        t0 = time.perf_counter()
        params = {"layers": [self._layer_params(k, node)
                             for k in range(self.n_layers)]}
        scores = pff._class_scores_chunked(params, const["x"], self.cfg)
        xn0 = ff_mlp._norm(self.neg.fn(
            jax.random.fold_in(self.kneg, chapter), self.cfg, params,
            const["x"], const["y"], scores))
        self._neg = (chapter, xn0)
        # publish toward every node that trains chapter c+1 while the
        # current chapter's tail (head task etc.) is still in flight
        if chapter + 1 < self.cfg.splits:
            for nxt in pff_dag.chapter_train_nodes(
                    self.schedule, self.num_nodes, self.n_layers,
                    chapter=chapter + 1):
                if nxt != node:
                    self._handoff.prefetch(("neg",), nxt, chapter, xn0)
        self._maybe_record(profile, node, "neg_gen", -1, chapter, t0, xn0)

    # ---- schedule drivers ------------------------------------------------
    def _run_chapter_owned(self, chapter, profile):
        """all_layers / federated / sequential: one node runs the whole
        chapter, computing its own forward features as it trains."""
        node = pff_dag.node_of(self.schedule, self.num_nodes, layer=0,
                               chapter=chapter)
        idx = self._const[node]["idx"]
        lrs, lrs_head = self._lrs(chapter)
        kc = jax.random.fold_in(self.key, chapter)
        acts, extras = self._chapter_inputs(chapter, node)
        for k in range(self.n_layers):
            lp = self._train_task(k, chapter, node, acts, extras, lrs,
                                  kc, profile)
            if k + 1 < self.n_layers:
                acts = tuple(self._fwd(lp, a) for a in acts)
        if self.has_head:
            self._head_task(chapter, node, idx, lrs_head, kc, profile)
        if self.has_neg and self.neg.needs_scores:
            self._neg_task(chapter, node, profile)

    def _run_chapter_single_layer(self, chapter, profile):
        """single_layer: node k owns layer k and re-runs the forward
        pass of layers < k over the train set (Algorithm 1 lines 3-5) —
        the load imbalance the paper observes. Weight hand-off: node k
        pulls layers 0..k-1's chapter-c weights as they appear."""
        lrs, lrs_head = self._lrs(chapter)
        kc = jax.random.fold_in(self.key, chapter)
        for k in range(self.n_layers):
            node = pff_dag.node_of(self.schedule, self.num_nodes,
                                   layer=k, chapter=chapter)
            acts, extras = self._chapter_inputs(chapter, node)
            for j in range(k):       # Algorithm-1 forward recompute
                w_j = self._layer_params(j, node)
                acts = tuple(self._fwd(w_j, a) for a in acts)
            self._train_task(k, chapter, node, acts, extras, lrs, kc,
                             profile)
        if self.has_head:
            node = pff_dag.head_node_of(self.schedule, self.num_nodes,
                                        n_layers=self.n_layers,
                                        chapter=chapter)
            self._head_task(chapter, node, None, lrs_head, kc, profile)
        if self.has_neg and self.neg.needs_scores:
            # the LAST node holds the full model freshest: it generates
            # and publishes for everyone (the paper's serialization).
            self._neg_task(chapter,
                           pff_dag.neg_node_of(self.schedule,
                                               self.num_nodes,
                                               chapter=chapter), profile)

    # ---- entry point -----------------------------------------------------
    def run(self, *, profile: bool = False) -> ExecResult:
        """Executes the schedule once. ``profile=True`` blocks after
        every task to collect per-task ``TaskRecord``s (destroys the
        overlap, so use a separate non-profiled run for makespan)."""
        cfg = self.cfg
        params = ff_mlp.init(jax.random.PRNGKey(cfg.seed), cfg)
        opt = ff_mlp.opt_init(params)
        self._records: List[pff.TaskRecord] = []
        self._busy = [0.0] * self.num_nodes
        self._neg: Tuple[int, object] = (-1, None)
        self._ver = [-1] * self.n_layers       # chapter of last train(k)
        self._head_ver = -1
        self._handoff = _Handoff(self.devices, self.overlap)

        t_start = time.perf_counter()
        # initial placement rides the timed window: it is part of the
        # schedule's real cost (the simulator's t=0 is the same state).
        self._states = [self.good.get_state(params, opt, k)
                        for k in range(self.n_layers)]
        self._head = (params["head"], opt["head"])
        for chapter in range(cfg.splits):
            if self.schedule == "single_layer":
                self._run_chapter_single_layer(chapter, profile)
            else:
                self._run_chapter_owned(chapter, profile)
        outs = [s[0] for s in self._states] + [self._head[0]]
        if self._neg[1] is not None:
            outs.append(self._neg[1])
        jax.block_until_ready(outs)
        makespan = time.perf_counter() - t_start

        final = self._pull({**self.good.export(self._states),
                            "head": self._head[0]}, 0)
        acc = ff_mlp.accuracy(final, self.task.x_test, self.task.y_test,
                              cfg.num_classes, self.good.eval_mode(cfg),
                              impl=self.impl)
        return ExecResult(final, self.schedule, self.num_nodes, makespan,
                          acc, self._records if profile else None,
                          list(self._busy) if profile else None,
                          dict(self._handoff.stats))


def run_pff_exec(cfg, task, schedule, num_nodes, *, devices=None,
                 profile=False) -> ExecResult:
    """Deprecated: use ``repro.api.fit(cfg, task, backend="executor",
    schedule=..., num_nodes=...)``."""
    import warnings

    warnings.warn("pff_exec.run_pff_exec is deprecated; use repro.api."
                  "fit(cfg, task, backend=\"executor\", schedule=..., "
                  "num_nodes=...)", DeprecationWarning, stacklevel=2)
    from repro import api
    return api.fit(cfg, task, backend="executor", schedule=schedule,
                   num_nodes=num_nodes, devices=devices,
                   profile=profile).raw


def params_bit_equal(a, b, *, with_head=False, with_local_heads=False):
    """True iff two FF-MLP params pytrees carry BIT-IDENTICAL layer
    (and optionally head / §4.4 local-head) weights — the executor's
    correctness oracle, shared by the selftest, the benchmark gate, and
    the example."""
    def leaves_equal(pa, pb):
        return all(bool(jnp.array_equal(pa[name], pb[name]))
                   for name in ("w", "b"))
    if len(a["layers"]) != len(b["layers"]):
        return False
    ok = all(leaves_equal(pa, pb)
             for pa, pb in zip(a["layers"], b["layers"]))
    if with_head:
        ok = ok and leaves_equal(a["head"], b["head"])
    if with_local_heads:
        ok = (ok and len(a["local_heads"]) == len(b["local_heads"])
              and all(leaves_equal(pa, pb) for pa, pb in
                      zip(a["local_heads"], b["local_heads"])))
    return ok


# ---------------------------------------------------------------------------
# Self-test: weight-stream bit-equality vs the sequential trainer.
# Run in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=4
# (tests/test_pff_exec.py does; `make pff-exec-smoke` exercises the same
# path through benchmarks/pff_exec.py).
# ---------------------------------------------------------------------------

def _check_case(schedule, nodes, splits, n_train, neg_mode, classifier,
                goodness_fn="sumsq", *, check_sim_bound=False,
                check_overlap_ab=False):
    """Trains one config both ways — THROUGH THE FACADE (``api.fit``) —
    and returns a list of failure strings (empty = the executor
    reproduced the sequential trainer's weight stream bit-exactly).

    check_overlap_ab: additionally runs the executor with the
    double-buffered hand-off DISABLED and requires the overlap-on and
    overlap-off weight streams to be bit-identical to each other (the
    prefetched copies must be the same bits as the on-demand pulls)."""
    from repro import api
    from repro.configs.ff_mlp import FFMLPConfig

    task = data_lib.mnist_like(n_train=n_train, n_test=200)
    cfg = FFMLPConfig(layer_sizes=(784, 128, 128), epochs=splits * 2,
                      splits=splits, neg_mode=neg_mode,
                      classifier=classifier, goodness_fn=goodness_fn,
                      batch_size=64, seed=0)
    if schedule == "federated":
        ref = api.fit(cfg, task, backend="federated", num_nodes=nodes)
    else:
        ref = api.fit(cfg, task, backend="sequential")
    res = api.fit(cfg, task, backend="executor", schedule=schedule,
                  num_nodes=nodes)

    failures = []
    perf_opt = goodness_fn == "perf_opt"
    if check_overlap_ab:
        off = api.fit(cfg, task, backend="executor", schedule=schedule,
                      num_nodes=nodes, overlap=False)
        stats_on, stats_off = res.raw.handoff, off.raw.handoff
        if not params_bit_equal(off.params, res.params,
                                with_head=classifier == "softmax",
                                with_local_heads=perf_opt):
            failures.append(f"{schedule}: overlap-on vs overlap-off "
                            "weight streams diverged")
        if stats_off["prefetch_issued"] != 0:
            failures.append(f"{schedule}: overlap=False still issued "
                            f"{stats_off['prefetch_issued']} prefetches")
        if nodes > 1 and stats_on["prefetch_hits"] == 0:
            failures.append(f"{schedule}: overlap=True never hit a "
                            f"prefetched slot ({stats_on})")
        print(f"  overlap A/B {schedule}: on={stats_on} off={stats_off}")
    if not params_bit_equal(ref.params, res.params,
                            with_head=classifier == "softmax",
                            with_local_heads=perf_opt):
        # diagnose which leaves diverged and by how much
        named = [(f"layer {k}", lp_ref, lp_ex) for k, (lp_ref, lp_ex) in
                 enumerate(zip(ref.params["layers"], res.params["layers"]))]
        if classifier == "softmax":
            named.append(("head", ref.params["head"], res.params["head"]))
        if perf_opt:
            named += [(f"local_head {k}", h_ref, h_ex)
                      for k, (h_ref, h_ex) in
                      enumerate(zip(ref.params["local_heads"],
                                    res.params["local_heads"]))]
        for label, pa, pb in named:
            for name in ("w", "b"):
                if not bool(jnp.array_equal(pa[name], pb[name])):
                    err = float(jnp.abs(pa[name] - pb[name]).max())
                    failures.append(f"{schedule}: {label} {name} diverged,"
                                    f" max|diff|={err:.3e}")
    sim_note = ""
    if check_sim_bound:
        # Sanity bound, deliberately loose (shared-core container, cold
        # executor caches): a real run can never beat the simulator's
        # perfect-overlap replay of the same median task times by 4x.
        sim = pff.simulate_schedule(ref.records, schedule, nodes)
        sim_note = f" sim={sim.makespan:.2f}s"
        if res.makespan < 0.25 * sim.makespan:
            failures.append(
                f"{schedule}: measured makespan {res.makespan:.3f}s "
                f"implausibly beats the simulator's perfect-overlap "
                f"prediction {sim.makespan:.3f}s by more than 4x")
    print(f"devices={len(jax.devices())} schedule={schedule} "
          f"nodes={nodes} neg={neg_mode} cls={classifier} "
          f"goodness={goodness_fn}: "
          f"exec acc={res.test_acc:.4f} seq acc={ref.test_acc:.4f} "
          f"makespan={res.makespan:.2f}s{sim_note} -> "
          + ("FAIL" if failures else "bit-exact"))
    return failures


# (schedule, nodes, splits, n_train, neg_mode, classifier[, goodness_fn])
# n_train=520: 520 % 64 != 0 — the tail-batch path is always exercised;
# federated shards of 130 hit a different (also non-divisible) tail.
# The perf_opt rows check the §4.4 path (fused per-layer local-head
# task) end to end, including the single_layer forward recompute.
# The _AB_CASES rows double as the double-buffering A/B gate: row 1
# (all_layers adaptive softmax) routes published negatives, the softmax
# head and full layer states through the next-chapter prefetch; row 3
# (single_layer random) covers the params-only forward-recompute
# fan-out; row 6 (single_layer adaptive softmax) covers the
# single_layer head-node and published-negatives fan-out paths, which
# rows 1/3 never create slots for.
_MATRIX = (
    ("all_layers", 4, 4, 520, "random", "goodness"),
    ("all_layers", 4, 3, 520, "adaptive", "softmax"),
    ("federated", 4, 4, 520, "random", "goodness"),
    ("single_layer", 2, 3, 520, "random", "goodness"),
    ("all_layers", 4, 3, 520, "random", "goodness", "perf_opt"),
    ("single_layer", 2, 3, 520, "random", "goodness", "perf_opt"),
    ("single_layer", 2, 3, 520, "adaptive", "softmax"),
)
# rows that additionally run the overlap-on vs overlap-off comparison
_AB_CASES = (1, 3, 6)


def _selftest(argv=None):
    import argparse

    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--matrix", action="store_true",
                   help="run the full schedule/neg/classifier matrix "
                        "in one process (what tests/test_pff_exec.py "
                        "invokes)")
    p.add_argument("--nodes", type=int, default=4)
    p.add_argument("--schedule", default="all_layers",
                   choices=list(pff_dag.SCHEDULES))
    p.add_argument("--splits", type=int, default=4)
    p.add_argument("--n-train", type=int, default=1000,
                   help="deliberately NOT divisible by the batch size, "
                        "so the tail-batch path is exercised too")
    p.add_argument("--neg-mode", default="random",
                   choices=list(strategies.negatives.names()))
    p.add_argument("--classifier", default="goodness",
                   choices=list(strategies.classifier.names()))
    p.add_argument("--goodness-fn", default="sumsq",
                   choices=list(strategies.goodness.names()))
    args = p.parse_args(argv)

    failures = []
    if args.matrix:
        for i, case in enumerate(_MATRIX):
            failures += _check_case(*case, check_sim_bound=i == 0,
                                    check_overlap_ab=i in _AB_CASES)
    else:
        failures = _check_case(args.schedule, args.nodes, args.splits,
                               args.n_train, args.neg_mode,
                               args.classifier, args.goodness_fn,
                               check_sim_bound=True,
                               check_overlap_ab=True)
    if failures:
        print("SELFTEST FAILED:\n  " + "\n  ".join(failures))
        return 1
    print("selftest OK: executor weight stream bit-exact vs the "
          "sequential trainer")
    return 0


if __name__ == "__main__":
    raise SystemExit(_selftest())
