"""PFF on real parallel devices, two ways, on 8 faked host devices:

  1. the paper's own schedule for real: ``repro.core.pff_exec`` runs
     All-Layers PFF with one device per paper "node", prints measured
     makespan next to the simulator's prediction, and verifies the
     distributed weight stream is BIT-IDENTICAL to sequential training;
  2. beyond-paper: the PFF pipeline mapped onto a (stage, data, model)
     device mesh — each stage owns a contiguous block range and
     activations flow forward via collective_permute; FF means NOTHING
     flows backward.

  PYTHONPATH=src python examples/pff_pod_pipeline.py
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro import api, data, optim
from repro.configs import get_config
from repro.configs.ff_mlp import FFMLPConfig
from repro.core import pff_exec, pff_pod
from repro.models import transformer

# --- 1. the paper's All-Layers schedule, executed for real ----------------
NODES = 4
mlp_cfg = FFMLPConfig(layer_sizes=(784, 256, 256), epochs=8, splits=8,
                      neg_mode="random", classifier="goodness",
                      batch_size=64, seed=0)
mlp_task = data.mnist_like(n_train=1024, n_test=200)
print(f"All-Layers PFF on {NODES} of {len(jax.devices())} host devices:")
seq = api.fit(mlp_cfg, mlp_task)                   # canonical + timings
res = api.fit(mlp_cfg, mlp_task, backend="executor",
              schedule="all_layers", num_nodes=NODES)
sim = api.simulate(seq, "all_layers", NODES)
same = pff_exec.params_bit_equal(seq.params, res.params)
print(f"  measured makespan {res.makespan:.2f}s | simulator predicts "
      f"{sim.makespan:.2f}s (speedup {sim.speedup:.2f}x)")
print(f"  distributed weight stream bit-identical to sequential: {same}")

# --- 2. beyond-paper: pipeline stages over a TPU-style mesh ---------------
# (api.fit(cfg, backend="pod", num_nodes=S) runs this on a (S, 1, 1)
# mesh; build the mesh by hand, as here, for data/model parallelism too)
cfg = get_config("tinyllama-1.1b").reduced()
cfg = dataclasses.replace(cfg, num_layers=4, groups=((("attn",), 4),))
mesh = jax.make_mesh((2, 2, 2), ("stage", "data", "model"))
print(f"mesh: {dict(mesh.shape)} — 2 pipeline stages x 2 data x 2 model")

key = jax.random.PRNGKey(0)
params = transformer.init(key, cfg)
opt = optim.adam_init(params)
B, S = 8, 64
inflight = pff_pod.init_inflight(cfg, B, S, stages=2)
# NOTE: step_fn is jitted internally (two executables) — wrapping it in
# an outer jax.jit re-fuses them and hits a jax-0.4.x GSPMD miscompile.
step_fn = pff_pod.make_pff_pod_step(cfg, mesh, lr=1e-3)

t0 = time.time()
with mesh:
    for i, tokens in enumerate(data.lm_batches(cfg.vocab, B, S, 40)):
        params, opt, inflight, m = step_fn(
            params, opt, {"tokens": jnp.asarray(tokens)}, inflight, i + 1)
        if (i + 1) % 10 == 0:
            print(f"step {i+1:3d}: pipeline FF loss "
                  f"{float(m['loss_ff']):.4f} ({time.time()-t0:.0f}s)")
print("pipeline ran with zero backward traffic between stages.")
