"""The ``repro.api`` facade: unified fit() over backends, strategy
registries (round-trip + custom registration), the CIFAR variant
end-to-end, and the deprecation shims for the old entry points."""
import dataclasses

import numpy as np
import pytest

from repro import api, data as data_lib
from repro.configs.ff_mlp import FFMLPConfig, PAPER_MLP_CIFAR


def _tiny_cfg(**kw):
    base = dict(layer_sizes=(784, 64), epochs=2, splits=2,
                neg_mode="random", classifier="goodness",
                batch_size=64, seed=0)
    base.update(kw)
    return FFMLPConfig(**base)


@pytest.fixture(scope="module")
def tiny_task():
    return data_lib.mnist_like(n_train=256, n_test=128)


# ---------------------------------------------------------------------------
# Strategy registries
# ---------------------------------------------------------------------------

def test_registry_round_trip_of_builtin_strategy_names():
    """Every builtin config string resolves to a strategy whose ``name``
    round-trips, for all three registries."""
    assert set(api.negatives.names()) >= {"adaptive", "fixed", "random"}
    assert set(api.goodness.names()) >= {"sumsq", "perf_opt"}
    assert set(api.classifier.names()) >= {"goodness", "softmax"}
    for reg in (api.negatives, api.goodness, api.classifier):
        for name in reg.names():
            assert reg.get(name).name == name
            assert name in reg


def test_registry_unknown_name_lists_choices():
    with pytest.raises(KeyError, match="random"):
        api.negatives.get("does_not_exist")


def test_register_custom_negatives_strategy(tiny_task):
    """A user-registered negatives strategy is reachable by config name
    through fit()."""
    from repro.core import ff

    def always_next_label(key, cfg, params, x, y, scores):
        labels = (y + 1) % cfg.num_classes
        return ff.overlay_label(x, labels, cfg.num_classes)

    api.register_negatives("next_label", always_next_label)
    try:
        assert "next_label" in api.negatives
        res = api.fit(_tiny_cfg(neg_mode="next_label"), tiny_task)
        assert 0.0 <= res.test_acc <= 1.0
        # duplicate registration must be loud unless overwrite=True
        with pytest.raises(ValueError):
            api.register_negatives("next_label", always_next_label)
        api.register_negatives("next_label", always_next_label,
                               overwrite=True)
    finally:
        api.negatives.unregister("next_label")
    assert "next_label" not in api.negatives


# ---------------------------------------------------------------------------
# fit() validation + backends
# ---------------------------------------------------------------------------

def test_fit_rejects_unknown_backend_and_strategies(tiny_task):
    with pytest.raises(ValueError, match="backend"):
        api.fit(_tiny_cfg(), tiny_task, backend="gpipe")
    with pytest.raises(KeyError, match="negatives"):
        api.fit(_tiny_cfg(neg_mode="nope"), tiny_task)
    # classifier/goodness pairing: perf_opt_* classifiers read the
    # local heads that only goodness_fn="perf_opt" trains
    with pytest.raises(ValueError, match="perf_opt"):
        api.fit(_tiny_cfg(classifier="perf_opt_all",
                          goodness_fn="sumsq"), tiny_task)


def test_fit_simulate_backend_returns_schedule_metrics(tiny_task):
    res = api.fit(_tiny_cfg(), tiny_task, backend="simulate",
                  schedule="all_layers", num_nodes=2)
    assert res.makespan > 0
    assert 0 < res.utilization <= 1.0 + 1e-9
    assert res.speedup <= 2 + 1e-6
    assert res.sim.schedule == "all_layers"
    # and the helper replays the same records under other schedules
    sim = api.simulate(res, "single_layer", 2)
    assert sim.makespan > 0


def test_fit_result_carries_records_and_params(tiny_task):
    res = api.fit(_tiny_cfg(classifier="softmax"), tiny_task)
    kinds = {r.kind for r in res.records}
    assert kinds >= {"train", "head", "neg_gen"}
    assert res.params["head"]["w"].shape[-1] == 10
    assert res.backend == "sequential" and res.num_nodes == 1


# ---------------------------------------------------------------------------
# CIFAR variant end-to-end (previously untested)
# ---------------------------------------------------------------------------

def test_cifar_variant_end_to_end_above_chance():
    """PAPER_MLP_CIFAR (reduced) + data.cifar_like through api.fit. The
    paper's Table 5 point: on the harder task the Performance-Optimized
    variant dominates plain goodness — and it must clear chance (0.1)
    by a wide margin."""
    task = data_lib.cifar_like(n_train=2560, n_test=400)
    cfg = dataclasses.replace(
        PAPER_MLP_CIFAR, layer_sizes=(task.dim, 300, 300),
        epochs=20, splits=2, goodness_fn="perf_opt", batch_size=64,
        seed=0)
    assert cfg.layer_sizes[0] == task.dim == 3072      # 32*32*3
    res = api.fit(cfg, task)
    assert res.test_acc > 0.3
    # registry round-trip of the exact strategy names this run used
    assert api.negatives.get(cfg.neg_mode).name == cfg.neg_mode
    assert api.goodness.get(cfg.goodness_fn).name == cfg.goodness_fn
    assert api.classifier.get(cfg.classifier).name == cfg.classifier


# ---------------------------------------------------------------------------
# Deprecated entry points
# ---------------------------------------------------------------------------

def test_old_entry_points_warn_and_delegate(tiny_task):
    """pff.train_ff_mlp / pff.train_federated / pff_exec.run_pff_exec
    still import, emit DeprecationWarning, and produce the facade's
    exact weight stream."""
    from repro.core import pff, pff_exec

    cfg = _tiny_cfg()
    facade = api.fit(cfg, tiny_task)
    with pytest.warns(DeprecationWarning):
        old = pff.train_ff_mlp(cfg, tiny_task)
    assert pff_exec.params_bit_equal(facade.params, old.params)

    fed_facade = api.fit(cfg, tiny_task, backend="federated", num_nodes=2)
    with pytest.warns(DeprecationWarning):
        old_fed = pff.train_federated(cfg, tiny_task, 2)
    assert pff_exec.params_bit_equal(fed_facade.params, old_fed.params)

    with pytest.warns(DeprecationWarning):
        old_exec = pff_exec.run_pff_exec(cfg, tiny_task, "sequential", 1)
    assert pff_exec.params_bit_equal(facade.params, old_exec.params)
    assert old_exec.makespan > 0


# ---------------------------------------------------------------------------
# Pod backend (beyond-paper pipeline) — minimal single-stage smoke
# ---------------------------------------------------------------------------

def test_pod_backend_runs_lm_config():
    from repro.configs import get_config

    cfg = get_config("tinyllama-1.1b").reduced()
    cfg = dataclasses.replace(cfg, num_layers=2, groups=((("attn",), 2),))
    res = api.fit(cfg, backend="pod", num_nodes=1, steps=2, batch=4,
                  seq=32)
    assert res.backend == "pod" and len(res.history) == 2
    assert np.isfinite(res.history[-1][1])


def test_pod_backend_rejects_mlp_config(tiny_task):
    with pytest.raises(ValueError, match="pod"):
        api.fit(_tiny_cfg(), tiny_task, backend="pod")
