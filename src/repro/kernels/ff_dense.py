"""Fused FF layer kernel: y = relu(x @ w + b), g = sum(y^2, axis=-1).

The Forward-Forward hot loop evaluates a dense layer AND its goodness for
both the positive and negative batch every step. Fusing the goodness
reduction into the matmul epilogue saves one full HBM round-trip of the
(M, N) activations — on TPU the (bm, bn) tile is reduced to a (bm,)
partial in VMEM right after the MXU matmul, while the tile is still hot.

Grid: (M/bm, N/bn), N innermost so the goodness partials for a row-block
accumulate across the j steps in the same VMEM scratch-free output block
(revisited blocks are legal because the TPU grid is executed
sequentially minor-to-major).

``norm=True`` additionally fuses Hinton's inter-layer length
normalization into the kernel epilogue: the goodness output IS the
squared norm, so once a row-block's g is fully accumulated the kernel
divides the activations by ``sqrt(g) + NORM_EPS`` in place. To stay
inside Pallas TPU's documented residency guarantee (an output block is
only preserved across CONSECUTIVE grid steps — the same rule the g
accumulation relies on; a revisit after eviction is undefined), the
normed kernel widens the y output block to the whole row (bm, N) with
a j-constant index map: the row block stays resident in VMEM for the
entire inner j sweep (~1 MB at the paper's N=2000), each step stores
its (bm, bn) column slice, and the j == nj-1 step normalizes the
resident block before it is written out. The epilogue therefore costs
ZERO extra HBM traffic — y goes out exactly once, already normalized,
and the separate norm reduction, sqrt, divide (and g's round-trip)
all disappear as XLA dispatches.

Tile defaults are MXU-aligned (128x128); K is streamed whole per tile —
for the paper's [784, 2000] layers x(bm, K) + w(K, bn) comfortably fit
VMEM (784*128*4 + 784*128*4 ~= 0.8 MB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# Hinton's inter-layer normalization epsilon — the ONE constant shared
# by the fused epilogue, the jnp oracle (ref.ff_dense_norm_ref) and
# ff_mlp._norm, so the kernel and XLA paths divide by the same number.
NORM_EPS = 1e-8

# Per-grid-step VMEM budget the autotuner's candidate filter enforces:
# half of a v5e core's ~16 MB, leaving headroom for Pallas's automatic
# input double-buffering. A candidate (bm, bn) whose resident blocks
# exceed this is never benchmarked — in particular the norm=True path,
# whose j-constant index map keeps the whole (bm, N) y row block
# resident across the inner sweep (the documented consecutive-revisit
# guarantee; an evicted block would make the epilogue divide undefined).
VMEM_BUDGET_BYTES = 8 * 2 ** 20


def vmem_block_bytes(K, N, bm, bn, *, norm=False, dtype_bytes=4):
    """Resident VMEM bytes of one forward grid step for blocks (bm, bn).

    The single source of truth for the autotuner's candidate filter:
    x (bm, K) + w (K, bn) + b (bn,) + the y output block + the (bm,)
    goodness accumulator. With ``norm=True`` the y block is the WHOLE
    (bm, Np) row (j-constant index map, see module docstring) — this is
    the VMEM row-residency invariant every tuned candidate must honor.
    """
    np_ = -(-N // bn) * bn if bn else N          # padded N
    y_cols = np_ if norm else bn
    return (bm * K + K * bn + bn + bm * y_cols) * dtype_bytes + bm * 4


def _tile_y_g(x_ref, w_ref, b_ref, g_ref, j):
    """The shared per-(i, j) compute: (bm, bn) activation tile plus the
    row-block goodness accumulation into the resident g block."""
    h = jnp.dot(x_ref[...], w_ref[...],
                preferred_element_type=jnp.float32)
    h = h + b_ref[...][None, :]
    y = jnp.maximum(h, 0.0)
    g_part = jnp.sum(y * y, axis=1)

    @pl.when(j == 0)
    def _init():
        g_ref[...] = g_part

    @pl.when(j != 0)
    def _acc():
        g_ref[...] = g_ref[...] + g_part

    return y


def _kernel(x_ref, w_ref, b_ref, y_ref, g_ref):
    j = pl.program_id(1)
    y = _tile_y_g(x_ref, w_ref, b_ref, g_ref, j)
    y_ref[...] = y.astype(y_ref.dtype)


def _kernel_norm(x_ref, w_ref, b_ref, y_ref, g_ref, *, bn, nj):
    # y_ref is the whole (bm, N) row block, resident across the j sweep
    # (j-constant index map — the consecutive-revisit accumulation
    # guarantee, same as g_ref); each step fills its column slice.
    j = pl.program_id(1)
    y = _tile_y_g(x_ref, w_ref, b_ref, g_ref, j)
    y_ref[:, pl.ds(j * bn, bn)] = y.astype(y_ref.dtype)

    @pl.when(j == nj - 1)
    def _normalize():
        # g is now fully accumulated; divide the still-resident row
        # block in place before it is written out — the fused epilogue.
        yy = y_ref[...].astype(jnp.float32)
        scale = jnp.sqrt(g_ref[...]) + NORM_EPS
        y_ref[...] = (yy / scale[:, None]).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret",
                                             "norm"))
def ff_dense(x, w, b, *, bm=128, bn=128, interpret=True, norm=False):
    """x: (M, K), w: (K, N), b: (N,) -> (y (M, N), goodness (M,) f32).

    norm=True: y is length-normalized in the kernel epilogue
    (``y / (sqrt(g) + NORM_EPS)``); g stays the RAW pre-norm goodness.
    """
    M, K = x.shape
    _, N = w.shape
    bm = min(bm, M)
    bn = min(bn, N)
    if M % bm or N % bn:          # pad to tile multiples
        Mp = -(-M // bm) * bm
        Np = -(-N // bn) * bn
        xp = jnp.pad(x, ((0, Mp - M), (0, 0)))
        wp = jnp.pad(w, ((0, 0), (0, Np - N)))
        bp = jnp.pad(b, (0, Np - N))
        # padded N columns are zero (w and b both padded with zeros), so
        # they contribute nothing to g — the in-kernel normalizer of the
        # real columns is exact.
        y, g = ff_dense(xp, wp, bp, bm=bm, bn=bn, interpret=interpret,
                        norm=norm)
        return y[:M, :N], g[:M]

    nj = N // bn
    grid = (M // bm, nj)
    in_specs = [
        pl.BlockSpec((bm, K), lambda i, j: (i, 0)),
        pl.BlockSpec((K, bn), lambda i, j: (0, j)),
        pl.BlockSpec((bn,), lambda i, j: (j,)),
    ]
    if norm:
        kernel = functools.partial(_kernel_norm, bn=bn, nj=nj)
        # whole-row y block, resident across the inner j sweep
        y_spec = pl.BlockSpec((bm, N), lambda i, j: (i, 0))
    else:
        kernel = _kernel
        y_spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    out_specs = [y_spec, pl.BlockSpec((bm,), lambda i, j: (i,))]
    y, g = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=[
            jax.ShapeDtypeStruct((M, N), x.dtype),
            jax.ShapeDtypeStruct((M,), jnp.float32),
        ],
        interpret=interpret,
    )(x, w, b)
    return y, g
