"""Beyond-paper example: the PFF pipeline mapped onto a (stage, data,
model) device mesh — each stage owns a contiguous block range and
activations flow forward via collective_permute; FF means NOTHING flows
backward. Runs on 8 faked host devices.

  PYTHONPATH=src python examples/pff_pod_pipeline.py
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro import data, optim
from repro.configs import get_config
from repro.core import pff_pod
from repro.models import transformer

cfg = get_config("tinyllama-1.1b").reduced()
cfg = dataclasses.replace(cfg, num_layers=4, groups=((("attn",), 4),))
mesh = jax.make_mesh((2, 2, 2), ("stage", "data", "model"))
print(f"mesh: {dict(mesh.shape)} — 2 pipeline stages x 2 data x 2 model")

key = jax.random.PRNGKey(0)
params = transformer.init(key, cfg)
opt = optim.adam_init(params)
B, S = 8, 64
inflight = pff_pod.init_inflight(cfg, B, S)
step_fn = jax.jit(pff_pod.make_pff_pod_step(cfg, mesh, lr=1e-3))

t0 = time.time()
with mesh:
    for i, tokens in enumerate(data.lm_batches(cfg.vocab, B, S, 40)):
        params, opt, inflight, m = step_fn(
            params, opt, {"tokens": jnp.asarray(tokens)}, inflight, i + 1)
        if (i + 1) % 10 == 0:
            print(f"step {i+1:3d}: stage-local FF loss "
                  f"{float(m['loss_ff']):.4f} ({time.time()-t0:.0f}s)")
print("pipeline ran with zero backward traffic between stages.")
