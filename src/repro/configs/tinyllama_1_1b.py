"""tinyllama-1.1b — llama2-architecture small model [arXiv:2401.02385]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="tinyllama-1.1b",
    arch_type="dense",
    num_layers=22,
    d_model=2048,
    n_heads=32,
    n_kv=4,
    d_ff=5632,
    vocab=32000,
    groups=((("attn",), 22),),
    source="arXiv:2401.02385 (TinyLlama)",
))
