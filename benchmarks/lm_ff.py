"""FF vs backprop on the synthetic LM (the framework's 'beyond-paper'
substrate check): both trainers on the same reduced arch + corpus, CE
trajectories compared. FF is not expected to beat BP on CE — the claim
is that it LEARNS (CE falls well below uniform) with purely local
updates, which is what makes the pipeline parallelism possible."""
from __future__ import annotations

import json
import math
import os
import time

import jax
import jax.numpy as jnp

from repro import data as data_lib, optim
from repro.configs import get_config
from repro.core import train as train_lib
from repro.models import transformer


def run(arch="qwen2-0.5b", steps=60, batch=8, seq=96, out_dir="experiments"):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    eval_tokens = jnp.asarray(next(iter(
        data_lib.lm_batches(cfg.vocab, 16, seq, 1, seed=123))))
    results = {}
    for name, make, lr in (("ff", train_lib.make_ff_train_step, 1e-3),
                           ("bp", train_lib.make_bp_train_step, 1e-3)):
        params = transformer.init(key, cfg)
        opt = optim.adam_init(params)
        step_fn = jax.jit(make(cfg, lr=lr))
        t0 = time.time()
        ce0 = float(train_lib.eval_ce(params, cfg, eval_tokens))
        for i, tokens in enumerate(data_lib.lm_batches(
                cfg.vocab, batch, seq, steps, seed=0)):
            params, opt, _ = step_fn(params, opt,
                                     {"tokens": jnp.asarray(tokens)}, i + 1)
        ce1 = float(train_lib.eval_ce(params, cfg, eval_tokens))
        results[name] = {"ce_start": round(ce0, 3), "ce_end": round(ce1, 3),
                         "wall_s": round(time.time() - t0, 1)}
        print(f"  {name}: CE {ce0:.3f} -> {ce1:.3f} "
              f"(uniform={math.log(cfg.vocab):.3f}) "
              f"[{results[name]['wall_s']}s]")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "lm_ff_vs_bp.json"), "w") as f:
        json.dump(results, f, indent=1)
    assert results["ff"]["ce_end"] < results["ff"]["ce_start"], \
        "FF failed to reduce CE"
    return results


if __name__ == "__main__":
    run()
