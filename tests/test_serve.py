"""Serving subsystem tests: version-vector consistency under concurrent
publish, queue admission/backpressure, batch forming, deterministic
traffic replay, the streaming ``data.Source`` protocol, and the e2e
train-while-serve smoke (accuracy improves across hot-swaps; the
published weight stream stays bit-exact)."""
import threading
import time

import numpy as np
import pytest

from repro import api, data as data_lib
from repro.configs.ff_mlp import FFMLPConfig
from repro.core import pff_exec
from repro.serve import (
    AdmissionQueue, Batcher, Replica, Request, RequestStream, ServeConfig,
    WeightBus,
)
from repro.serve import engine as serve_engine
from repro.serve.traffic import traffic as traffic_registry


def _layer_piece(k, version, dim=4):
    """A fake per-layer export piece (shape of ``good.export([state])``)
    whose bits encode (layer, version) — lets assertions detect a torn
    snapshot by content, not just by version tag."""
    return {"layers": [{"w": np.full((dim, dim), version * 100 + k,
                                     np.float32),
                        "b": np.zeros(dim, np.float32)}]}


# ---------------------------------------------------------------------------
# WeightBus + Replica: the consistency contract
# ---------------------------------------------------------------------------

def test_bus_exposes_only_fully_published_versions():
    bus = WeightBus(3, has_head=True)
    bus.publish_layer(0, 0, _layer_piece(0, 0))
    bus.publish_layer(1, 0, _layer_piece(1, 0))
    assert bus.next_snapshot(-10) is None          # layer 2 + head missing
    bus.publish_layer(2, 0, _layer_piece(2, 0))
    assert bus.next_snapshot(-10) is None          # head still missing
    bus.publish_head(0, {"w": np.ones((3, 2), np.float32)})
    ver, params, vec, _ = bus.next_snapshot(-10)
    assert ver == 0 and vec == [0, 0, 0, 0]
    assert len(params["layers"]) == 3 and "head" in params
    # content check: every layer really is the version-0 publication
    for k, lp in enumerate(params["layers"]):
        assert lp["w"][0, 0] == 0 * 100 + k


def test_bus_snapshots_step_in_version_order():
    bus = WeightBus(1)
    for v in (2, 0, 1):                            # out-of-order assembly
        bus.publish_layer(0, v, _layer_piece(0, v))
    seen, after = [], -10
    while True:
        rec = bus.next_snapshot(after)
        if rec is None:
            break
        seen.append(rec[0])
        after = rec[0]
    assert seen == [0, 1, 2]                       # oldest-first, one at a time


def test_bus_copies_published_trees():
    """Copy-on-publish: mutating (or donating) the producer's buffer
    after publication must not reach the parked snapshot."""
    bus = WeightBus(1)
    piece = _layer_piece(0, 0)
    bus.publish_layer(0, 0, piece)
    piece["layers"][0]["w"][:] = -1.0              # producer clobbers its copy
    _, params, _, _ = bus.next_snapshot(-10)
    assert float(params["layers"][0]["w"][0, 0]) == 0.0


def test_concurrent_publish_never_yields_torn_snapshot():
    """The tentpole invariant: a consumer hammering the bus while a
    producer publishes layer-by-layer never observes a half-published
    layer set — every snapshot's version vector is uniform AND every
    layer's content matches its tagged version."""
    n_layers, n_versions = 3, 12
    bus = WeightBus(n_layers)
    stop = threading.Event()

    def producer():
        for v in range(n_versions):
            for k in range(n_layers):
                bus.publish_layer(k, v, _layer_piece(k, v))
                time.sleep(0.0003)                 # widen the torn window
        stop.set()

    th = threading.Thread(target=producer)
    th.start()
    installed = []
    after = -10
    while not (stop.is_set() and bus.next_snapshot(after) is None):
        rec = bus.next_snapshot(after)
        if rec is None:
            continue
        ver, params, vec, _ = rec
        assert vec == [ver] * n_layers
        for k, lp in enumerate(params["layers"]):
            assert float(lp["w"][0, 0]) == ver * 100 + k, \
                f"torn snapshot: layer {k} carries the wrong version"
        installed.append(ver)
        after = ver
    th.join()
    assert installed == sorted(installed)          # monotone
    assert installed == list(range(n_versions))    # nothing skipped


def test_replica_counts_version_vector_violations():
    r = Replica(10, max_batch=8)
    params = {"layers": [_layer_piece(0, 0)["layers"][0]]}
    assert r.install(0, params, [0], time.perf_counter())
    # non-uniform vector: half-published layer set
    assert not r.install(1, params, [1, 0], time.perf_counter())
    # non-monotone: rolling the replica backward
    assert not r.install(0, params, [0], time.perf_counter())
    assert r.consistency_violations == 2
    assert r.version == 0 and len(r.swaps) == 1


# ---------------------------------------------------------------------------
# Queue + batcher: admission control and the batching knobs
# ---------------------------------------------------------------------------

def _req(i, t=0.0):
    return Request(id=i, x=np.zeros(4, np.float32), label=0, t_arrival=t)


def test_queue_sheds_on_full_and_keeps_fifo_order():
    q = AdmissionQueue(4)
    results = [q.offer(_req(i)) for i in range(6)]
    assert results == [True] * 4 + [False] * 2
    assert q.stats == {"accepted": 4, "rejected": 2, "depth_peak": 4}
    assert [r.id for r in q.take(10)] == [0, 1, 2, 3]
    assert len(q) == 0
    assert q.offer(_req(9))                        # room again after take


def test_batcher_max_batch_and_max_wait():
    q = AdmissionQueue(64)
    b = Batcher(max_batch=4, max_wait_s=0.5)
    for i in range(3):
        q.offer(_req(i, t=0.0))
    assert b.form(q, now=0.1) == []                # 3 < 4 and young
    assert [r.id for r in b.form(q, now=0.6)] == [0, 1, 2]   # head waited
    for i in range(5):
        q.offer(_req(10 + i, t=1.0))
    assert [r.id for r in b.form(q, now=1.0)] == [10, 11, 12, 13]  # full
    assert b.form(q, now=1.0) == []                # 1 left, young again
    assert [r.id for r in b.form(q, now=1.0, flush=True)] == [14]


# ---------------------------------------------------------------------------
# Traffic: registry + deterministic replay
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_source():
    return data_lib.source_of(data_lib.mnist_like(n_train=64, n_test=256))


@pytest.mark.parametrize("name", ["uniform", "zipf", "bursty"])
def test_traffic_streams_replay_deterministically(tiny_source, name):
    def grab(seed):
        s = RequestStream(tiny_source, traffic_registry.get(name),
                          rate=100.0, seed=seed)
        return s.take(300)

    a, b, c = grab(7), grab(7), grab(8)
    assert [t for t, _ in a] == [t for t, _ in b]
    assert all(ra.label == rb.label and np.array_equal(ra.x, rb.x)
               for (_, ra), (_, rb) in zip(a, b))
    # a different seed is a different stream (arrivals or payloads)
    assert ([t for t, _ in a] != [t for t, _ in c]
            or any(ra.label != rc.label
                   for (_, ra), (_, rc) in zip(a, c)))
    # arrival clock strictly accumulates across take() calls
    times = [t for t, _ in a]
    assert all(t2 >= t1 for t1, t2 in zip(times, times[1:]))


def test_zipf_traffic_skews_the_class_mix(tiny_source):
    s = RequestStream(tiny_source, traffic_registry.get("zipf"),
                      rate=100.0, num_classes=10, seed=0)
    labels = [r.label for _, r in s.take(2000)]
    counts = sorted(np.bincount(labels, minlength=10), reverse=True)
    assert counts[0] > 3 * max(counts[-1], 1)      # head class dominates


def test_register_traffic_and_unknown_name():
    api.register_traffic("test_constant",
                         lambda rng, n, rate, C: (np.full(n, 1.0 / rate),
                                                  np.zeros(n, np.int32)))
    try:
        assert "test_constant" in api.traffic
        with pytest.raises(ValueError, match="unknown traffic"):
            ServeConfig(traffic="no_such_traffic")
        assert ServeConfig(traffic="test_constant").traffic == "test_constant"
    finally:
        traffic_registry.unregister("test_constant")


# ---------------------------------------------------------------------------
# data.Source protocol (ROADMAP item 5 start)
# ---------------------------------------------------------------------------

def test_prototype_source_task_matches_classic_helpers():
    src = data_lib.mnist_source(seed=3)
    t1 = src.task(n_train=128, n_test=32)
    t2 = data_lib.mnist_like(seed=3, n_train=128, n_test=32)
    assert np.array_equal(t1.x_train, t2.x_train)
    assert np.array_equal(t1.y_test, t2.y_test)
    assert isinstance(src, data_lib.Source)


def test_sources_are_pure_functions_of_split_and_seed():
    for src in (data_lib.mnist_source(0),
                data_lib.source_of(data_lib.mnist_like(n_train=64,
                                                       n_test=32))):
        x1, y1 = src.sample("serve", 16, seed=5)
        x2, y2 = src.sample("serve", 16, seed=5)
        assert np.array_equal(x1, x2) and np.array_equal(y1, y2)
        x3, _ = src.sample("serve", 16, seed=6)
        x4, _ = src.sample("other", 16, seed=5)
        assert not np.array_equal(x1, x3)          # seed is an axis
        assert not np.array_equal(x1, x4)          # split is an axis
        assert x1.shape == (16, src.dim) and y1.dtype == np.int32


# ---------------------------------------------------------------------------
# Facade plumbing
# ---------------------------------------------------------------------------

def test_fit_rejects_serve_on_non_executor_backends():
    cfg = FFMLPConfig(layer_sizes=(784, 32), epochs=2, splits=2,
                      neg_mode="random", classifier="goodness",
                      batch_size=64, seed=0)
    with pytest.raises(ValueError, match="executor"):
        api.fit(cfg, None, backend="sequential",
                serve=api.ServeConfig())
    with pytest.raises(TypeError, match="ServeConfig"):
        api.fit(cfg, None, backend="executor", serve={"rate": 100})
    with pytest.raises(ValueError, match="task or"):
        api.serve(cfg)
    with pytest.raises(TypeError, match="knob"):
        api.serve(cfg, data_lib.mnist_like(n_train=64, n_test=32),
                  bogus_knob=3)


def test_launch_serve_shim_warns_and_delegates(monkeypatch):
    from repro.launch import serve as launch_serve

    seen = {}
    monkeypatch.setattr(launch_serve, "lm_decode",
                        lambda cfg, **kw: seen.update(kw) or "sentinel")
    with pytest.warns(DeprecationWarning, match="lm_decode"):
        out = launch_serve.serve(None, batch=2, prompt_len=8, gen=4)
    assert out == "sentinel" and seen["batch"] == 2


# ---------------------------------------------------------------------------
# E2E: train-while-serve
# ---------------------------------------------------------------------------

def test_train_while_serve_e2e_smoke():
    """The acceptance-criteria invariants on a single device: at least
    one completed hot-swap per chapter, zero consistency violations,
    request accuracy IMPROVES across the swap timeline, and live
    publication leaves the training weight stream bit-exact."""
    task = data_lib.mnist_like(n_train=2560, n_test=400)
    cfg = FFMLPConfig(layer_sizes=(784, 256, 256), epochs=100, splits=4,
                      neg_mode="random", classifier="goodness",
                      batch_size=64, seed=0)
    res = api.serve(cfg, task, traffic="zipf", schedule="sequential",
                    num_nodes=1, rate=300.0, max_batch=64, seed=1)

    assert res.slo["consistency_violations"] == 0
    # init snapshot (-1) + one per completed chapter
    swap_versions = [s["version"] for s in res.swaps]
    assert swap_versions == [-1] + list(range(cfg.splits))
    assert res.slo["requests"] > 0
    assert all(s["staleness_s"] >= 0 for s in res.swaps)

    # accuracy-vs-time: the last-version window must beat the
    # untrained (-1) window decisively (chance is 0.1)
    curve = res.accuracy_by_version
    first, last = min(curve), max(curve)
    assert last == cfg.splits - 1
    assert curve[last]["n"] >= 64                  # final_probe window
    assert curve[last]["accuracy"] > curve[first]["accuracy"] + 0.2
    assert curve[last]["accuracy"] > 0.4

    # per-request records carry the full lifecycle
    r0 = res.records[0]
    assert {"id", "t_arrival", "t_done", "latency", "version", "pred",
            "label", "correct"} <= set(r0)
    assert res.timings["train_s"] > 0 and res.timings["serve_s"] > 0

    # publication is read-only: same weight stream as plain training
    ref = api.fit(cfg, task)                       # sequential trainer
    assert pff_exec.params_bit_equal(ref.params, res.fit.params)
    assert res.fit.serve is res
    assert res.fit.test_acc == ref.test_acc


def test_serve_static_replays_bit_identically():
    """Serve-only mode: same params + same ServeConfig seed => the same
    request ids, labels and predictions, regardless of wall clock."""
    task = data_lib.mnist_like(n_train=512, n_test=256)
    cfg = FFMLPConfig(layer_sizes=(784, 64), epochs=2, splits=2,
                      neg_mode="random", classifier="goodness",
                      batch_size=64, seed=0)
    params = api.fit(cfg, task).params

    def run():
        r = api.serve(cfg, task, params=params, traffic="bursty",
                      n_requests=192, seed=5, rate=2000.0)
        return [(x["id"], x["label"], x["pred"]) for x in r.records]

    a, b = run(), run()
    assert a == b and len(a) == 192


def test_engine_summarize_counts_sheds():
    """A rate far above what max_wait admits per tick must shed: the
    queue capacity bounds memory and the SLO block reports the drop."""
    task = data_lib.mnist_like(n_train=256, n_test=128)
    cfg = FFMLPConfig(layer_sizes=(784, 32), epochs=2, splits=2,
                      neg_mode="random", classifier="goodness",
                      batch_size=64, seed=0)
    params = api.fit(cfg, task).params
    res = api.serve(cfg, task, params=params, traffic="uniform",
                    n_requests=256, rate=1e6, max_batch=16,
                    queue_cap=32, seed=0)
    slo = res.slo
    # every scored request was an accepted one; the burst beyond the
    # queue capacity was shed, not buffered
    assert slo["requests"] == slo["accepted"]
    assert slo["accepted"] + slo["rejected"] == 256
    assert slo["rejected"] > 0 and slo["shed_rate"] > 0.0
    assert slo["queue_depth_peak"] <= 32
    assert slo["latency_p99_ms"] >= slo["latency_p50_ms"]
    raw = res.raw
    assert isinstance(raw, serve_engine.EngineResult)
