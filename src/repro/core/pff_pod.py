"""PFF over the pod axis — the paper's pipeline, TPU-native (beyond-paper).

The paper pipelines FF layer-training across socket-connected CPU nodes.
On a multi-pod TPU system the same idea maps onto the mesh: the ``pod``
axis becomes the PIPELINE-STAGE axis. Each pod owns a contiguous block
range; within a pod the usual (data, model) sharding applies.

Because FF deletes the backward pass, the inter-pod traffic is ONE
forward activation tensor per microbatch, sent via collective_permute —
no gradient return traffic, no bubble-filling schedule needed. This is
Figure 2 of the paper realized in ICI collectives:

  pod 0: block range [0, L/2)   trains on microbatch t
  pod 1: block range [L/2, L)   trains on microbatch t-1 (activations
                                 received from pod 0 last step)

Implementation: ``shard_map`` over the pod axis. Every pod executes the
same program on its own stacked slice of the layer parameters; a
carried "inflight activation" buffer plays the role of the pipeline
register. After S steps the pipeline is full and every pod trains every
step — utilization (S - P + 1)/S, exactly the paper's chapter pipeline.

The per-pod inner step reuses ``repro.core.train``'s scan body (local
FF losses + inline Adam), so numerics per block are identical to the
single-pod path; only WHERE a block trains changes — the paper's claim.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import optim, sharding
from repro.core import ff
from repro.models import blocks, common
from repro.models.mlp import NO_DIST


def make_pff_pod_step(cfg, mesh, *, lr=1e-3, seed=0, theta=None):
    """Returns step_fn(stage_params, stage_opt, batch, step) for a mesh
    with axes ("stage", "data", "model").

    stage_params: the SINGLE group's stacked params (R, ...) where R is
    divisible by the stage count; stage s owns rows [s*R/P, (s+1)*R/P).
    batch: {"tokens": (B, S+1)} — every stage needs the tokens only for
    the embedding stage; activations flow between stages.

    Restriction (documented): cfg must be single-group (uniform pattern),
    which covers 8/10 assigned archs; the hybrid/enc-dec archs use the
    single-pod FF step.
    """
    assert len(cfg.groups) == 1, "pod-pipeline needs a uniform stack"
    pattern, repeat = cfg.groups[0]
    stages = mesh.shape["stage"]
    assert repeat % stages == 0, (repeat, stages)
    theta = theta if theta is not None else cfg.ff.theta

    def local_ff_update(x, unit_p, unit_m, unit_v, is_pos, step):
        """One block-unit FF update (same math as core.train)."""
        ctx = {"causal": True, "dist": NO_DIST}

        def loss_fn(up):
            h = jax.lax.stop_gradient(x)
            total = jnp.zeros(())
            for kind, bp in zip(pattern, up):
                h_sg = jax.lax.stop_gradient(h)
                y, moe_aux = blocks.block_apply(bp, cfg, kind, h_sg, ctx)
                g = ff.mean_goodness(y - h_sg)
                total = total + ff.ff_loss_masked(g, is_pos, theta) \
                    + 0.01 * moe_aux
                h = y
            return total, h

        (loss, y), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(unit_p)
        # Data-parallel correctness: each data shard sees a DIFFERENT
        # slice of the stacked [pos; neg] batch (the first shards are
        # all-positive, the last all-negative), so the shard-local
        # gradients MUST be averaged over the data axis before the
        # update — the out_specs claim params replicated over "data",
        # and without this pmean the replicas silently diverge (and the
        # unchecked-replication assembly turns that into NaNs on
        # multi-axis meshes).
        grads = jax.lax.pmean(grads, "data")
        loss = jax.lax.pmean(loss, "data")
        new_p, st = optim.adam_update(unit_p, grads,
                                      {"m": unit_m, "v": unit_v},
                                      lr=lr, step=step)
        return jax.lax.stop_gradient(y), new_p, st, loss

    def stage_step(gp, gm, gv, x_in, is_pos, step):
        """Run this pod's block range over the incoming activations."""
        def body(carry, leaf):
            up, um, uv = leaf
            y, new_p, st, loss = local_ff_update(
                carry, up, um, uv, is_pos, step)
            return y, (new_p, st["m"], st["v"], loss)

        x_out, ys = jax.lax.scan(body, x_in, (gp, gm, gv))
        return x_out, ys[0], ys[1], ys[2], ys[3].sum()

    def pod_program(gp, gm, gv, x_in, inflight, is_pos, step):
        """shard_map body over the stage axis. inflight: the pipeline
        activation register, stage-local slice (1, 2B_local, S, d) of
        the global (stages, 2B, S, d) array — the explicit leading
        stage axis is what makes its out_specs sound (each stage's
        register genuinely differs, so it must be SHARDED over "stage",
        not falsely claimed replicated)."""
        sid = jax.lax.axis_index("stage")
        # stage 0 consumes the fresh embedding; others consume inflight
        x = jnp.where(sid == 0, x_in, inflight[0])
        y, new_gp, new_gm, new_gv, loss = stage_step(
            gp, gm, gv, x, is_pos, step)
        # forward the produced activations to the next stage (the FF
        # pipeline register) — pure forward traffic, no backward edge.
        perm = [(s, int((s + 1) % stages)) for s in range(stages)]
        new_inflight = jax.lax.ppermute(y, "stage", perm)[None]
        # total pipeline loss: the scalar leaves the shard_map with
        # out_specs P(), i.e. claimed replicated over EVERY mesh axis —
        # without this psum the claim is false over "stage" (each stage
        # had its own stage-local sum), which is exactly the kind of
        # unsound spec that miscompiles under jit (NaN weights on
        # multi-axis meshes) and that check_rep/check_vma rejects.
        loss = jax.lax.psum(loss, "stage")
        return new_gp, new_gm, new_gv, new_inflight, loss

    gspec = P("stage")          # stacked layer axis sharded over stages

    # check=True: every out_specs replication claim is now sound
    # (grads/loss pmean'd over "data", loss psum'd over "stage"), so
    # let the checker prove it instead of trusting us. Built ONCE so the
    # jit wrapper below caches a single executable.
    smap2 = jax.jit(sharding.shard_map(
        pod_program, mesh=mesh,
        in_specs=(gspec, gspec, gspec, P("data"),
                  P("stage", "data"), P("data"), P()),
        out_specs=(gspec, gspec, gspec, P("stage", "data"), P()),
        check=True))

    @jax.jit
    def _prep(embed, tokens, step):
        """Negative corruption + embedding lookup (the per-step glue)."""
        tokens = tokens[:, :-1]
        B = tokens.shape[0]
        key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
        neg = ff.corrupt_tokens(key, tokens, cfg.vocab)
        x_tok = jnp.concatenate([tokens, neg], axis=0)
        is_pos = jnp.concatenate(
            [jnp.ones((B,)), jnp.zeros((B,))]).astype(jnp.float32)
        return jnp.take(embed, x_tok, axis=0), is_pos

    def step_fn(params, opt_state, batch, inflight, step):
        """params: {"embed": ..., "groups": (stacked,)}; inflight is the
        pipeline register pytree returned by the previous call.

        Already jitted INTERNALLY as two executables (glue, pipeline) —
        do NOT wrap it in an outer jax.jit: on jax 0.4.x, fusing the
        PRNG negative-corruption glue into the same XLA program as the
        manually-sharded pipeline miscompiles under GSPMD (NaN weights
        on any data x model mesh; the split is the workaround).
        """
        x, is_pos = _prep(params["embed"], batch["tokens"],
                          jnp.asarray(step, jnp.int32))
        gp = params["groups"][0]
        gm = opt_state["m"]["groups"][0]
        gv = opt_state["v"]["groups"][0]
        new_gp, new_gm, new_gv, new_inflight, loss = smap2(
            gp, gm, gv, x, inflight, is_pos,
            jnp.asarray(step, jnp.int32))
        new_params = dict(params)
        new_params["groups"] = (new_gp,)
        new_m = dict(opt_state["m"]); new_m["groups"] = (new_gm,)
        new_v = dict(opt_state["v"]); new_v["groups"] = (new_gv,)
        return new_params, {"m": new_m, "v": new_v}, new_inflight, {
            "loss_ff": loss}

    return step_fn


def init_inflight(cfg, batch, seq, stages=1):
    """Zero pipeline register: (stages, 2*batch, seq, d_model) — one
    activation slot per pipeline stage (sharded over the stage axis)."""
    return jnp.zeros((stages, 2 * batch, seq, cfg.d_model),
                     common.dtype_of(cfg))
