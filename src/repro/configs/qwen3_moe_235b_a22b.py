"""qwen3-moe-235b-a22b — 128 experts, top-8 [hf:Qwen/Qwen3-30B-A3B family].

94 layers, d_model=4096, 64 heads (GQA kv=4, head_dim=128), expert
d_ff=1536, vocab=151936, MoE 128e top-8, qk-norm.
"""
from repro.configs.base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-moe-235b-a22b",
    arch_type="moe",
    num_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv=4,
    head_dim=128,
    d_ff=1536,
    vocab=151936,
    qk_norm=True,
    rope_theta=1e6,
    groups=((("attn",), 94),),
    moe=MoEConfig(num_experts=128, top_k=8, expert_ff=1536,
                  capacity_factor=1.25),
    source="hf:Qwen/Qwen3-30B-A3B (scaled per assignment)",
))
