"""Dense (gated) MLP and Mixture-of-Experts.

MoE uses MegaBlocks-style sort-based dispatch with a fixed per-shard
capacity. Under distribution it runs inside ``shard_map``:

  tokens sharded on the batch ('data') axis, experts sharded on the
  'model' axis (expert parallelism), expert weights additionally sharded
  on 'data' (ZeRO-3) and all-gathered per layer. Dispatch:
  local sort -> all_to_all over 'model' -> per-expert matmul ->
  all_to_all back -> weighted combine.

For decode-sized token counts a dense-local-experts path is used (every
device runs its local experts over all tokens, psum over 'model'): this
matches real decode behaviour — memory-bound on expert weights — and
avoids degenerate capacities.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import sharding
from repro.models import common


# ---------------------------------------------------------------------------
# Distribution context threaded through the model
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Dist:
    """Names of mesh axes; None disables explicit collectives (smoke/CPU)."""
    mesh: object = None
    batch_axes: Tuple[str, ...] = ()     # axes sharding the batch/token dim
    model_axis: Optional[str] = None     # tensor/expert-parallel axis
    fsdp_axis: Optional[str] = None      # axis sharding expert d_model (ZeRO)

    @property
    def enabled(self):
        return self.mesh is not None and self.model_axis is not None

    def model_size(self):
        return self.mesh.shape[self.model_axis] if self.enabled else 1

    def constrain_batch(self, x):
        """Pin an activation's leading (batch) dim to the data axes —
        GSPMD sometimes loses batch sharding through scan bodies +
        value_and_grad; this keeps every layer batch-parallel."""
        if not self.enabled or x is None:
            return x
        P = jax.sharding.PartitionSpec
        ba = self.batch_axes
        if not ba:
            return x
        spec = P(ba if len(ba) > 1 else ba[0],
                 *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, spec))


NO_DIST = Dist()


# ---------------------------------------------------------------------------
# Dense MLP
# ---------------------------------------------------------------------------

def dense_mlp_init(key, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": common.dense_init(k1, (d_model, d_ff), dtype),
        "wg": common.dense_init(k2, (d_model, d_ff), dtype),
        "wo": common.dense_init(k3, (d_ff, d_model), dtype),
    }


def dense_mlp_apply(p, x, act_name="silu"):
    act = common.activation(act_name)
    h = act(x @ p["wg"]) * (x @ p["wi"])
    return h @ p["wo"]


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def moe_init(key, moe, d_model, dtype):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    p = {
        "router": common.dense_init(k1, (d_model, moe.num_experts),
                                    jnp.float32),
        "wi": common.dense_init(k2, (moe.num_experts, d_model, moe.expert_ff),
                                dtype, fan_in=d_model),
        "wg": common.dense_init(k3, (moe.num_experts, d_model, moe.expert_ff),
                                dtype, fan_in=d_model),
        "wo": common.dense_init(k4, (moe.num_experts, moe.expert_ff, d_model),
                                dtype, fan_in=moe.expert_ff),
    }
    if moe.num_shared:
        p["shared"] = dense_mlp_init(
            k5, d_model, moe.num_shared * moe.shared_ff, dtype)
    return p


def _capacity(tokens, top_k, num_experts, cf):
    c = int(tokens * top_k / num_experts * cf)
    return max(8, -(-c // 8) * 8)        # round up to multiple of 8


def _router(p, x, moe):
    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eids = jax.lax.top_k(probs, moe.top_k)           # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    # switch-style load-balance loss (computed locally, pmean'd by caller)
    me = probs.mean(axis=0)                                # (E,)
    one_hot = jax.nn.one_hot(eids[:, 0], moe.num_experts, dtype=jnp.float32)
    ce = one_hot.mean(axis=0)
    aux = moe.num_experts * jnp.sum(me * ce)
    return gate, eids, aux


def _sorted_dispatch(x, eids, num_experts, capacity):
    """x: (T, d), eids: (T, k) -> buf (E, C, d), plus combine metadata."""
    T, d = x.shape
    k = eids.shape[1]
    flat_e = eids.reshape(-1)                              # (Tk,)
    sort_idx = jnp.argsort(flat_e)                         # stable
    sorted_e = flat_e[sort_idx]
    counts = jnp.bincount(flat_e, length=num_experts)
    offsets = jnp.concatenate([jnp.zeros(1, counts.dtype),
                               jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(T * k, dtype=jnp.int32) - offsets[sorted_e]
    valid = pos_in_e < capacity
    dest = jnp.where(valid, sorted_e * capacity + pos_in_e,
                     num_experts * capacity)               # overflow -> drop
    buf = jnp.zeros((num_experts * capacity + 1, d), x.dtype)
    buf = buf.at[dest].set(x[sort_idx // k], mode="drop")
    return buf[:-1].reshape(num_experts, capacity, d), (sort_idx, dest, valid)


def _combine(buf_out, meta, T, k, gate):
    sort_idx, dest, valid = meta
    d = buf_out.shape[-1]
    flat = buf_out.reshape(-1, d)
    rows = jnp.where(valid, dest, 0)[..., None]
    y_sorted = jnp.take_along_axis(
        flat, jnp.broadcast_to(rows, (T * k, d)), axis=0)
    y_sorted = jnp.where(valid[:, None], y_sorted, 0)
    inv = jnp.argsort(sort_idx)
    y_tk = y_sorted[inv].reshape(T, k, d)
    return jnp.einsum("tkd,tk->td", y_tk.astype(jnp.float32),
                      gate).astype(buf_out.dtype)


def _expert_ffn(wi, wg, wo, tokens, act_name):
    act = common.activation(act_name)
    h = act(jnp.einsum("ecd,edf->ecf", tokens, wg))
    h = h * jnp.einsum("ecd,edf->ecf", tokens, wi)
    return jnp.einsum("ecf,efd->ecd", h, wo)


def _moe_local(p, x, moe, act_name, dist: Dist):
    """Body that runs per-shard (or globally when dist is disabled).

    x: (T, d) local tokens; p['wi'] etc are LOCAL shards when dist.enabled:
    (E_local, d_local, ff). Gathers weights over the fsdp axis, dispatches
    tokens over the model axis with all_to_all.
    """
    T, d = x.shape
    gate, eids, aux = _router(p, x, moe)
    n_model = dist.model_size()
    wi, wg, wo = p["wi"], p["wg"], p["wo"]
    if dist.enabled and dist.fsdp_axis is not None:
        wi = jax.lax.all_gather(wi, dist.fsdp_axis, axis=1, tiled=True)
        wg = jax.lax.all_gather(wg, dist.fsdp_axis, axis=1, tiled=True)
        wo = jax.lax.all_gather(wo, dist.fsdp_axis, axis=2, tiled=True)

    decode_sized = T <= 64 * moe.top_k
    if decode_sized:
        # dense-local-experts: (T, E_l) gates for the local expert slice
        e_l = wi.shape[0]
        shard_id = (jax.lax.axis_index(dist.model_axis)
                    if dist.enabled else 0)
        gates_full = jnp.zeros((T, moe.num_experts), jnp.float32)
        gates_full = jax.vmap(
            lambda g, e, row: row.at[e].set(g))(gate, eids, gates_full)
        local_slice = jax.lax.dynamic_slice(
            gates_full, (0, shard_id * e_l), (T, e_l))
        h = _expert_ffn(wi, wg, wo, jnp.broadcast_to(x, (e_l, T, d))
                        .transpose(0, 1, 2), act_name)       # (E_l, T, d)
        y = jnp.einsum("etd,te->td", h.astype(jnp.float32), local_slice)
        if dist.enabled:
            y = jax.lax.psum(y, dist.model_axis)
        y = y.astype(x.dtype)
    else:
        cap = _capacity(T, moe.top_k, moe.num_experts, moe.capacity_factor)
        buf, meta = _sorted_dispatch(x, eids, moe.num_experts, cap)
        if dist.enabled:
            e_l = moe.num_experts // n_model
            buf = buf.reshape(n_model, e_l, cap, d)
            buf = jax.lax.all_to_all(buf, dist.model_axis, split_axis=0,
                                     concat_axis=0, tiled=False)
            # (n_model, e_l, cap, d) axis0 = source shard
            tokens = buf.transpose(1, 0, 2, 3).reshape(e_l, n_model * cap, d)
            out = _expert_ffn(wi, wg, wo, tokens, act_name)
            out = out.reshape(e_l, n_model, cap, d).transpose(1, 0, 2, 3)
            out = jax.lax.all_to_all(out, dist.model_axis, split_axis=0,
                                     concat_axis=0, tiled=False)
            buf_out = out.reshape(moe.num_experts, cap, d)
        else:
            buf_out = _expert_ffn(wi, wg, wo, buf, act_name)
        y = _combine(buf_out, meta, T, moe.top_k, gate)

    if "shared" in p:
        # shared experts run tensor-parallel: ff sharded on model axis
        ys = dense_mlp_apply(p["shared"], x, act_name)
        if dist.enabled:
            ys = jax.lax.psum(ys, dist.model_axis)
        y = y + ys
    if dist.enabled and dist.batch_axes:
        aux = jax.lax.pmean(aux, dist.batch_axes)
    return y, aux


def moe_apply(p, x, moe, act_name, dist: Dist = NO_DIST):
    """x: (B, S, d) global (pjit-land). Returns (y, aux_loss)."""
    B, S, d = x.shape

    def body(p_, x_):
        xt = x_.reshape(-1, d)
        y, aux = _moe_local(p_, xt, moe, act_name, dist)
        return y.reshape(x_.shape), aux

    if not dist.enabled:
        return body(p, x)

    P = jax.sharding.PartitionSpec
    ba = dist.batch_axes
    ma, fa = dist.model_axis, dist.fsdp_axis
    in_x = P(ba if ba else None, None, None)
    specs = {
        "router": P(None, None),
        "wi": P(ma, fa, None),
        "wg": P(ma, fa, None),
        "wo": P(ma, None, fa),
    }
    if "shared" in p:
        specs["shared"] = {"wi": P(None, ma), "wg": P(None, ma),
                           "wo": P(ma, None)}
    fn = sharding.shard_map(
        body, mesh=dist.mesh, in_specs=(specs, in_x),
        out_specs=(in_x, P()))
    return fn(p, x)
