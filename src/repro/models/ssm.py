"""Mamba-2 SSD (state-space duality) block, chunked algorithm
[arXiv:2405.21060], n_groups=1.

Train/prefill: chunked dual form — quadratic attention-like compute inside
chunks of length ``chunk`` + a linear scan carrying the (H, hd, N) state
across chunks. Decode: O(1) recurrent update.

The chunk inner computation is the compute hot-spot and has a Pallas
kernel in ``repro.kernels.mamba2_ssd`` (validated against this module).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common


def init(key, cfg):
    """Single fused input projection (z | x | b | c | dt), as in the
    reference Mamba-2: one matmul instead of five — 5x fewer backward
    activation-cotangent all-reduces under tensor parallelism (§Perf)."""
    s = cfg.ssm
    d = cfg.d_model
    d_inner = s.expand * d
    H = d_inner // s.head_dim
    N = s.state_dim
    ks = jax.random.split(key, 4)
    dt = jnp.exp(jax.random.uniform(ks[2], (H,), jnp.float32) *
                 (jnp.log(0.1) - jnp.log(0.001)) + jnp.log(0.001))
    dtype = common.dtype_of(cfg)
    return {
        "in_proj": common.dense_init(
            ks[0], (d, 2 * d_inner + 2 * N + H), dtype),
        "conv_w": (jax.random.normal(ks[1], (s.conv_width, d_inner + 2 * N),
                                     jnp.float32) * 0.1).astype(dtype),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(dt)),        # inverse softplus
        "norm": jnp.zeros((d_inner,), jnp.float32),
        "out_proj": common.dense_init(ks[3], (d_inner, d), dtype),
    }


def _causal_conv(x, w):
    """Depthwise causal conv. x: (B, S, C); w: (cw, C)."""
    cw = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(cw):
        out = out + pad[:, i:i + x.shape[1], :].astype(jnp.float32) * w[i]
    return out.astype(x.dtype)


def _proj_inputs(p, cfg, x):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    N = s.state_dim
    zxbcdt = x @ p["in_proj"]
    return jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + N,
                 2 * d_inner + 2 * N], axis=-1)


def ssd_chunked(xh, dt, A, b, c, chunk, h0=None):
    """Chunked SSD scan, streaming over chunks.

    xh: (B, S, H, hd); dt: (B, S, H) post-softplus; A: (H,) negative;
    b, c: (B, S, N). Returns y: (B, S, H, hd) and final state (B, H, hd, N).

    One ``lax.scan`` over the nc chunks carries the (B, H, hd, N) state;
    each iteration computes the dual (quadratic) intra-chunk term and the
    state contribution. Peak live memory is ONE chunk's (B, L, L, H)
    decay tensor — independent of sequence length (the naive all-chunks
    formulation needs B*S*L*H floats, terabytes at 32k+).
    """
    B, S, H, hd = xh.shape
    N = b.shape[-1]
    L = min(chunk, S)
    if S % L:                       # pad to a chunk multiple (dt=0 rows
        pad = L - S % L             # contribute nothing to the state)
        zpad = lambda a: jnp.pad(a, [(0, 0), (0, pad)] +
                                 [(0, 0)] * (a.ndim - 2))
        y, hT = ssd_chunked(zpad(xh), zpad(dt), A, zpad(b), zpad(c),
                            chunk, h0)
        return y[:, :S], hT
    nc = S // L
    f32 = jnp.float32

    dA = (dt.astype(f32) * A).reshape(B, nc, L, H)           # negative
    xbar = (xh.astype(f32) * dt.astype(f32)[..., None]).reshape(
        B, nc, L, H, hd)
    bc = b.astype(f32).reshape(B, nc, L, N)
    cc = c.astype(f32).reshape(B, nc, L, N)
    mask = jnp.tril(jnp.ones((L, L), bool))

    def body(h, inp):
        dA_c, xbar_c, b_c, c_c = inp      # (B,L,H),(B,L,H,hd),(B,L,N)x2
        cums = jnp.cumsum(dA_c, axis=1)                      # (B,L,H)
        # intra-chunk dual form
        seg = cums[:, :, None, :] - cums[:, None, :, :]      # (B,i,j,H)
        decay = jnp.where(mask[None, :, :, None], jnp.exp(seg), 0.0)
        scores = jnp.einsum("bin,bjn->bij", c_c, b_c)
        y = jnp.einsum("bij,bijh,bjhd->bihd", scores, decay, xbar_c)
        # inter-chunk: contribution of the carried state
        decay_in = jnp.exp(cums)                             # (B,L,H)
        y = y + jnp.einsum("bin,bhdn,bih->bihd", c_c, h, decay_in)
        # state update
        last = cums[:, -1:, :]                               # (B,1,H)
        decay_out = jnp.exp(last - cums)                     # (B,L,H)
        st = jnp.einsum("bjh,bjn,bjhd->bhdn", decay_out, b_c, xbar_c)
        h = h * jnp.exp(last[:, 0, :])[..., None, None] + st
        return h, y

    h0 = jnp.zeros((B, H, hd, N), f32) if h0 is None else h0.astype(f32)
    hT, ys = jax.lax.scan(
        body, h0, (dA.transpose(1, 0, 2, 3), xbar.transpose(1, 0, 2, 3, 4),
                   bc.transpose(1, 0, 2, 3), cc.transpose(1, 0, 2, 3)))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)
    return y, hT


def forward(p, cfg, x, h0=None, conv0=None, return_cache=False):
    """Full-sequence forward. x: (B, S, d) -> (B, S, d).

    With ``return_cache`` also returns {"h": final state, "conv": raw
    pre-conv tail} ready for ``decode_step``.
    """
    s = cfg.ssm
    B, S, d = x.shape
    d_inner = s.expand * d
    H = d_inner // s.head_dim
    z, xin, b, c, dt_raw = _proj_inputs(p, cfg, x)
    xbc = jnp.concatenate([xin, b, c], axis=-1)
    if conv0 is not None:
        xbc_ext = jnp.concatenate([conv0, xbc], axis=1)
        conv_tail = xbc_ext[:, -(s.conv_width - 1):]
        xbc = _causal_conv(xbc_ext, p["conv_w"])[:, conv0.shape[1]:]
    else:
        conv_tail = xbc[:, -(s.conv_width - 1):]    # raw (pre-conv) tail
        xbc = _causal_conv(xbc, p["conv_w"])
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x.dtype)
    xin, b, c = jnp.split(xbc, [d_inner, d_inner + s.state_dim], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = xin.reshape(B, S, H, s.head_dim)
    y, hT = ssd_chunked(xh, dt, A, b, c, s.chunk, h0)
    y = y + p["D"][:, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = common.rms_norm(y.astype(x.dtype), p["norm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    if return_cache:
        return out, {"h": hT, "conv": conv_tail}
    return out


def init_cache(cfg, batch, dtype):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.head_dim
    return {
        "h": jnp.zeros((batch, H, s.head_dim, s.state_dim), jnp.float32),
        "conv": jnp.zeros((batch, s.conv_width - 1,
                           d_inner + 2 * s.state_dim), dtype),
    }


def decode_step(p, cfg, cache, x):
    """x: (B, d) single token. Returns (y (B, d), new cache)."""
    s = cfg.ssm
    B, d = x.shape
    d_inner = s.expand * d
    H = d_inner // s.head_dim
    z, xin, b, c, dt_raw = _proj_inputs(p, cfg, x)
    xbc = jnp.concatenate([xin, b, c], axis=-1)               # (B, C)
    conv_in = jnp.concatenate([cache["conv"], xbc[:, None]], axis=1)
    conv_out = jnp.einsum("bwc,wc->bc", conv_in.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32))
    xbc = jax.nn.silu(conv_out).astype(x.dtype)
    xin, b, c = jnp.split(xbc, [d_inner, d_inner + s.state_dim], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    xh = xin.reshape(B, H, s.head_dim).astype(jnp.float32)
    dA = jnp.exp(dt * A)                                      # (B, H)
    h = cache["h"] * dA[..., None, None] + jnp.einsum(
        "bh,bhd,bn->bhdn", dt, xh, b.astype(jnp.float32))
    y = jnp.einsum("bn,bhdn->bhd", c.astype(jnp.float32), h)
    y = y + p["D"][:, None] * xh
    y = y.reshape(B, d_inner) * jax.nn.silu(z.astype(jnp.float32))
    y = common.rms_norm(y.astype(x.dtype), p["norm"], cfg.norm_eps)
    new_cache = {"h": h, "conv": conv_in[:, 1:]}
    return y @ p["out_proj"], new_cache
