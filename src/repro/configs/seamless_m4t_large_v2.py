"""seamless-m4t-large-v2 — encoder-decoder, multimodal [arXiv:2308.11596].

24 transformer-backbone layers interpreted as 12 encoder + 12 decoder
(text decoder with cross-attention). d_model=1024, 16 heads (kv=16),
d_ff=8192, vocab=256206. The audio frontend (mel + conv feature
extractor) is a stub: input_specs() supplies precomputed frame embeddings
of shape (batch, enc_seq, d_model).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="seamless-m4t-large-v2",
    arch_type="audio",
    num_layers=12,            # decoder layers
    enc_layers=12,            # encoder layers
    enc_dec=True,
    enc_seq=1024,             # audio frames delivered by the stub frontend
    d_model=1024,
    n_heads=16,
    n_kv=16,
    d_ff=8192,
    vocab=256206,
    groups=(
        (("attn",), 12),      # encoder (bidirectional)
        (("xdec",), 12),      # decoder (self-attn + cross-attn + mlp)
    ),
    act="relu",
    source="arXiv:2308.11596 (SeamlessM4T v2)",
))
