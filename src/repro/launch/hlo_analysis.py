"""Static analyzer for optimized (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` on the CPU backend counts every
computation ONCE — ``lax.scan``-generated while loops are not multiplied
by their trip count, which under-counts our layer-stacked models by the
layer count. This module re-derives the roofline quantities from the HLO
text itself:

  flops       — 2*M*N*K summed over every ``dot`` (MXU flops; elementwise
                flops are ignored, as in standard roofline practice),
                weighted by the product of enclosing while-loop trip
                counts.
  bytes       — HBM traffic model: for every top-level op in non-fusion
                computations, output bytes + operand bytes (a fusion node
                counts only its boundary IO — its internals live in
                VMEM/registers, exactly what post-fusion HBM traffic
                means), weighted by trip counts.
  collectives — result-shape bytes per collective type, trip-weighted
                (the per-device program's view).

Trip counts come from the largest integer constant in each while's
condition computation — exact for scan-generated loops.
"""
from __future__ import annotations

import re
from typing import Dict, List

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8,
                "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4,
                "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(
    r"(f64|f32|f16|bf16|s64|s32|s16|s8|u64|u32|u16|u8|pred|c64|c128)"
    r"\[([\d,]*)\]")

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _parse_shapes(text):
    """All (dtype, dims) shapes in a type string (handles tuples)."""
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        shape = tuple(int(d) for d in dims.split(",") if d)
        out.append((dt, shape))
    return out


def _nbytes(shapes):
    total = 0
    for dt, shape in shapes:
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


class HloModule:
    def __init__(self, text: str):
        self.comps: Dict[str, List[str]] = {}
        self.entry = None
        cur = None
        for line in text.splitlines():
            m = _COMP_RE.match(line)
            if m:
                cur = m.group(2)
                self.comps[cur] = []
                if m.group(1):
                    self.entry = cur
            elif cur is not None and line.strip() and line.strip() != "}":
                self.comps[cur].append(line)
        if self.entry is None:      # fall back: last computation
            self.entry = list(self.comps)[-1] if self.comps else None
        # symbol tables per computation: var -> type string
        self.symbols: Dict[str, Dict[str, str]] = {}
        for cname, lines in self.comps.items():
            tbl = {}
            for line in lines:
                dm = _DEF_RE.match(line)
                if dm:
                    var, rhs = dm.group(1), dm.group(2)
                    # type = everything before the opcode name
                    tm = re.match(r"((?:\([^)]*\)|[\w\[\],\s{}:#*]+?))\s+"
                                  r"([\w\-]+)\(", rhs)
                    if tm:
                        tbl[var] = tm.group(1)
            # parameters: "%p = f32[..] parameter(0)" handled above
            self.symbols[cname] = tbl
        self._weights = self._compute_weights()
        self._fusion_bodies = self._find_fusion_bodies()

    # -- call graph -------------------------------------------------------
    def _compute_weights(self):
        weights = {c: 0 for c in self.comps}
        if self.entry is None:
            return weights
        weights[self.entry] = 1
        # iterate to fixpoint (call graph is a DAG; few passes suffice)
        for _ in range(12):
            changed = False
            for cname, lines in self.comps.items():
                w = weights.get(cname, 0)
                if w == 0:
                    continue
                for line in lines:
                    # while loops
                    wm = re.search(r"while\(.*?\).*?condition=%?"
                                   r"([\w\.\-]+),\s*body=%?([\w\.\-]+)",
                                   line)
                    if wm:
                        cond, body = wm.groups()
                        tm = re.search(
                            r'known_trip_count[":{\s]*[n":\s]*(\d+)', line)
                        trip = (int(tm.group(1)) if tm
                                else self._trip_count(cond))
                        for tgt, mult in ((cond, trip), (body, trip)):
                            nw = w * mult
                            if nw > weights.get(tgt, 0):
                                weights[tgt] = nw
                                changed = True
                        continue
                    # fusion / call / reducers / conditionals
                    for attr in ("calls", "to_apply"):
                        fm = re.search(attr + r"=%?([\w\.\-]+)", line)
                        if fm:
                            tgt = fm.group(1)
                            if w > weights.get(tgt, 0):
                                weights[tgt] = w
                                changed = True
                    cm = re.search(r"branch_computations=\{([^}]*)\}", line)
                    if cm:
                        for tgt in re.findall(r"%?([\w\.\-]+)",
                                              cm.group(1)):
                            if w > weights.get(tgt, 0):
                                weights[tgt] = w
                                changed = True
            if not changed:
                break
        return weights

    def _trip_count(self, cond_name):
        best = 1
        for line in self.comps.get(cond_name, ()):
            for c in re.findall(r"constant\((\d+)\)", line):
                best = max(best, int(c))
        return best

    def _find_fusion_bodies(self):
        bodies = set()
        for lines in self.comps.values():
            for line in lines:
                if re.search(r"\bfusion\(", line):
                    fm = re.search(r"calls=%?([\w\.\-]+)", line)
                    if fm:
                        bodies.add(fm.group(1))
                for attr in ("to_apply",):
                    fm = re.search(attr + r"=%?([\w\.\-]+)", line)
                    if fm:
                        bodies.add(fm.group(1))   # reducers: skip for bytes
        return bodies

    # -- queries ------------------------------------------------------------
    def _operand_vars(self, line):
        call = line.split("(", 1)
        if len(call) < 2:
            return []
        args = call[1].split(")", 1)[0]
        return re.findall(r"%([\w\.\-]+)", args)

    def flops(self):
        """Trip-weighted dot flops (everywhere, incl. fusion bodies)."""
        total = 0.0
        for cname, lines in self.comps.items():
            w = self._weights.get(cname, 0)
            if w == 0:
                continue
            tbl = self.symbols[cname]
            for line in lines:
                if not re.search(r"=\s*[^=]*\bdot\(", line):
                    continue
                dm = _DEF_RE.match(line)
                if not dm:
                    continue
                out_shapes = _parse_shapes(dm.group(2).split("dot(")[0])
                if not out_shapes:
                    continue
                out_elems = 1
                for d in out_shapes[0][1]:
                    out_elems *= d
                # contracted dims from lhs
                ops = self._operand_vars(line)
                lhs_type = tbl.get(ops[0], "") if ops else ""
                lhs_shapes = _parse_shapes(lhs_type)
                cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
                k = 1
                if cm and lhs_shapes:
                    for d in cm.group(1).split(","):
                        if d:
                            k *= lhs_shapes[0][1][int(d)]
                total += w * 2.0 * out_elems * k
        return total

    def bytes_accessed(self):
        """Trip-weighted boundary IO of top-level ops (HBM traffic model).

        In-place ops are credited as such (XLA:TPU updates buffers in
        place): dynamic-update-slice counts 2x the UPDATE bytes (read +
        write of the touched region, not the whole buffer);
        dynamic-slice counts 2x the result bytes.
        """
        total = 0.0
        skip_ops = ("parameter", "constant", "get-tuple-element", "tuple",
                    "bitcast", "while", "conditional")
        for cname, lines in self.comps.items():
            w = self._weights.get(cname, 0)
            if w == 0 or cname in self._fusion_bodies:
                continue
            tbl = self.symbols[cname]
            for line in lines:
                dm = _DEF_RE.match(line)
                if not dm:
                    continue
                rhs = dm.group(2)
                om = re.match(r"(?:\([^)]*\)|[\w\[\],\s{}:#*]+?)\s+"
                              r"([\w\-]+)\(", rhs)
                if not om:
                    continue
                op = om.group(1)
                if op in skip_ops:
                    continue
                result_b = _nbytes(
                    _parse_shapes(rhs.split(om.group(1) + "(")[0]))
                ops_v = self._operand_vars(line)
                if op == "dynamic-update-slice":
                    upd = ops_v[1] if len(ops_v) > 1 else None
                    ub = _nbytes(_parse_shapes(tbl.get(upd, "")))
                    total += w * 2 * ub
                    continue
                if op == "dynamic-slice":
                    total += w * 2 * result_b
                    continue
                io = result_b
                for v in ops_v:
                    if v in tbl:
                        io += _nbytes(_parse_shapes(tbl[v]))
                total += w * io
        return total

    def collective_bytes(self):
        out = {c: 0 for c in COLLECTIVES}
        counts = {c: 0 for c in COLLECTIVES}
        for cname, lines in self.comps.items():
            w = self._weights.get(cname, 0)
            if w == 0:
                continue
            for line in lines:
                dm = _DEF_RE.match(line)
                if not dm:
                    continue
                rhs = dm.group(2)
                for coll in COLLECTIVES:
                    if re.search(rf"\b{coll}(?:-start)?\(", rhs):
                        out[coll] += w * _nbytes(
                            _parse_shapes(rhs.split(coll)[0]))
                        counts[coll] += w
                        break
        return out, counts


def analyze(hlo_text: str):
    mod = HloModule(hlo_text)
    coll, counts = mod.collective_bytes()
    return {
        "flops": mod.flops(),
        "bytes": mod.bytes_accessed(),
        "collective_by_type": coll,
        "collective_counts": counts,
        "collective_bytes": float(sum(coll.values())),
    }
