"""Dispatch layer: TPU -> Pallas kernel, anything else -> jnp oracle.

Model code imports from here; tests cross-validate both paths. On this
CPU container the Pallas path runs in interpret mode (set
``force_pallas=True``); on a real TPU it compiles to Mosaic.
"""
from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.ff_dense import ff_dense as _ff_dense_pallas
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.mamba2_ssd import mamba2_ssd as _ssd_pallas


def _on_tpu():
    return jax.default_backend() == "tpu"


def ff_dense(x, w, b, *, force_pallas=False):
    if _on_tpu() or force_pallas:
        return _ff_dense_pallas(x, w, b, interpret=not _on_tpu())
    return ref.ff_dense_ref(x, w, b)


def flash_attention(q, k, v, *, causal=True, window=None,
                    force_pallas=False):
    if _on_tpu() or force_pallas:
        return _flash_pallas(q, k, v, causal=causal, window=window,
                             interpret=not _on_tpu())
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window)


def mamba2_ssd(xbar, dA, b, c, *, chunk=128, force_pallas=False):
    if _on_tpu() or force_pallas:
        return _ssd_pallas(xbar, dA, b, c, chunk=chunk,
                           interpret=not _on_tpu())
    return ref.mamba2_ssd_ref(xbar, dA, b, c)
