"""qwen3-8b — qk-norm, GQA [hf:Qwen/Qwen3-8B]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-8b",
    arch_type="dense",
    num_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv=8,
    head_dim=128,
    d_ff=12288,
    vocab=151936,
    qk_norm=True,
    rope_theta=1e6,
    groups=((("attn",), 36),),
    source="hf:Qwen/Qwen3-8B",
))
