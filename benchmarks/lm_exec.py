"""LM chapters on the real executor: bit-equality, CE budget, speedup.

The `make lm-exec-smoke` CI gate (ISSUE 10): a tiny qwen2-0.5b-shaped
transformer stack trained by the paper's chapter schedule on the
real-text BPE pipeline (``data.text_source``), driven through
``core/pff_exec.LMExecutor`` across 4 faked devices. Three result
families land in ``BENCH_lm_exec.json``:

  1. bit-equality rows — the executor's weight stream vs the
     sequential ``pff_lm.train_chapters`` reference, All-Layers and
     Single-Layer at N=4 (``benchmarks/run.py`` exits non-zero on any
     divergence: this is the acceptance-criteria gate),
  2. an eval-CE row — the chapter-trained model scored by held-out CE
     against the joint-FF step (``core/train.py``) at an equal
     per-block update budget on the SAME text source; the gate is
     ``ce_exec <= ce_joint + ce_budget`` (the schedules optimize the
     same local objectives, so chapter training must land in the same
     CE neighborhood),
  3. measured-vs-simulated rows — warm-cache executor makespan (with
     the overlap on/off A/B) next to ``pff.simulate_schedule``'s
     replay of the sequential trainer's task records under the same
     node assignment.

CPU-container caveat (same as ``benchmarks/pff_exec.py``): the faked
devices share the host cores, so measured speedup is bounded by the
core budget — the honest comparison is measured vs simulated under the
same contention. Needs >= 4 devices: export
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` before jax is
imported (``make lm-exec-smoke`` does; this module also sets it when
imported before jax).
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys

if "jax" not in sys.modules:                       # pragma: no cover
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax
import jax.numpy as jnp

from repro import api, data as data_lib, optim
from repro.configs import get_config
from repro.core import pff, pff_exec, pff_lm, train as train_lib
from repro.models import transformer

NODES = 4
CE_BUDGET = 1.5          # nats: chapter-FF vs joint-FF at equal updates


def _setup(quick):
    blocks = 4
    chapters, steps, batch, seq = ((3, 3, 4, 16) if quick
                                   else (4, 8, 8, 32))
    cfg = get_config("qwen2-0.5b").reduced()
    cfg = dataclasses.replace(cfg, num_layers=blocks,
                              groups=((("attn",), blocks),))
    source = data_lib.text_source(vocab=cfg.vocab, seq_len=seq, seed=0)
    return cfg, source, dict(chapters=chapters, steps_per_chapter=steps,
                             batch=batch, lr=3e-3)


def _joint_ff_ce(cfg, source, kw, eval_tokens):
    """The joint-FF step (core/train.py) at the same per-block update
    budget on the same text source — the CE yardstick."""
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    opt = optim.adam_init(params)
    step_fn = jax.jit(train_lib.make_ff_train_step(cfg, lr=kw["lr"]))
    joint_steps = kw["chapters"] * kw["steps_per_chapter"]
    for i in range(joint_steps):
        blk = source.blocks("train", kw["batch"], seed=5000 + i)
        params, opt, _ = step_fn(params, opt,
                                 {"tokens": jnp.asarray(blk)}, i + 1)
    return float(train_lib.eval_ce(params, cfg, eval_tokens))


def run(quick=True, out_path=None):
    if out_path is None:
        out_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                os.pardir, "BENCH_lm_exec.json")
    cfg, source, kw = _setup(quick)
    devices = jax.devices()
    n_dev = len(devices)
    print(f"devices: {n_dev} x {devices[0].platform}")
    eval_tokens = jnp.asarray(source.blocks("val", 16, seed=321))
    ce_init = float(train_lib.eval_ce(
        transformer.init(jax.random.PRNGKey(0), cfg), cfg, eval_tokens))

    # sequential reference: weight-stream oracle + task timings + CE
    ref = api.fit(cfg, source, backend="sequential", **kw)
    print(f"sequential train_chapters: eval CE {ref.eval_ce:.4f} "
          f"(init {ce_init:.4f}) in {ref.makespan:.1f}s")

    ce_joint = _joint_ff_ce(cfg, source, kw, eval_tokens)
    results = {
        "config": {"arch": "qwen2-0.5b (reduced)",
                   "blocks": cfg.groups[0][1], "vocab": cfg.vocab,
                   "seq_len": source.seq_len, "bpe_vocab":
                   int(source.encoder.n_vocab), **{k: v for k, v in
                                                   kw.items()},
                   "backend": jax.default_backend(), "devices": n_dev,
                   "cpu_count": os.cpu_count()},
        "note": ("measured speedup on a CPU container is bounded by the "
                 "shared host core budget; compare measured vs simulated "
                 "under the same contention. CE gate: chapter-FF "
                 "(sequential AND executor, bit-identical) within "
                 "ce_budget of the joint-FF step at equal per-block "
                 "updates on the same BPE text source."),
        "ce": {"init": round(ce_init, 4), "joint_ff": round(ce_joint, 4),
               "chapter_seq": round(ref.eval_ce, 4),
               "budget": CE_BUDGET},
        "rows": [],
    }
    failures = []
    if ref.eval_ce > ce_joint + CE_BUDGET:
        failures.append(
            f"chapter-FF eval CE {ref.eval_ce:.4f} exceeds joint-FF "
            f"{ce_joint:.4f} + budget {CE_BUDGET}")
    print(f"joint-FF eval CE {ce_joint:.4f} | chapter-FF "
          f"{ref.eval_ce:.4f} (budget +{CE_BUDGET})")

    # serial yardstick: the sequential run's per-(kind, layer) median
    # durations summed — the same compile-outlier smoothing (and the
    # same denominator) simulate_schedule uses, so measured and
    # simulated speedups are directly comparable (ref.makespan itself
    # is cold and would inflate the measured number past N).
    ref_durs = pff.task_durations(ref.records)
    serial_s = sum(ref_durs[(r.kind, r.layer)] for r in ref.records)
    for schedule in ("all_layers", "single_layer"):
        sim = pff.simulate_schedule(ref.records, schedule, NODES)
        row = {"schedule": schedule, "nodes": NODES,
               "sim": {"makespan_s": sim.makespan,
                       "speedup": sim.speedup,
                       "utilization": sim.utilization}}
        if n_dev < NODES:
            row["measured"] = None
            row["note"] = (f"needs {NODES} devices, found {n_dev} — set "
                           "XLA_FLAGS=--xla_force_host_platform_device_"
                           f"count={NODES} (see make lm-exec-smoke)")
            results["rows"].append(row)
            print(f"{schedule:>13} N={NODES}: sim speedup "
                  f"{sim.speedup:5.2f}x | not measured (too few devices)")
            continue
        ex = pff_exec.LMExecutor(cfg, source, schedule, NODES,
                                 devices=devices, seed=0, **kw)
        prof = ex.run(profile=True)   # compile warm-up + busy estimate
        timed = ex.run()              # warm-cache makespan
        bit = pff_lm.lm_params_bit_equal(ref.params, timed.params)
        if not bit:
            failures.append(f"{schedule} N={NODES}: executor weight "
                            "stream diverged from train_chapters")
        ce_exec = float(train_lib.eval_ce(timed.params, cfg,
                                          eval_tokens))
        ex_off = pff_exec.LMExecutor(cfg, source, schedule, NODES,
                                     devices=devices, seed=0,
                                     overlap=False, **kw)
        ex_off.run()                  # compile warm-up
        off = ex_off.run()
        durs = pff.task_durations(prof.records)
        busy = sum(durs[(r.kind, r.layer)] for r in prof.records)
        row["weights_bit_exact_vs_sequential"] = bit
        row["measured"] = {
            "makespan_s": timed.makespan,
            "speedup": (serial_s / timed.makespan
                        if timed.makespan else 1.0),
            "utilization_est": (min(1.0, busy / (NODES * timed.makespan))
                                if timed.makespan else 1.0),
            "eval_ce": round(ce_exec, 4),
            "handoff": timed.handoff,
            "makespan_s_no_overlap": off.makespan,
            "overlap_speedup": (off.makespan / timed.makespan
                                if timed.makespan else 1.0),
            "handoff_no_overlap": off.handoff,
        }
        results["rows"].append(row)
        m = row["measured"]
        print(f"{schedule:>13} N={NODES}: sim speedup {sim.speedup:5.2f}x"
              f" | measured makespan {m['makespan_s']:6.2f}s "
              f"speedup {m['speedup']:5.2f}x ce {ce_exec:.4f} | "
              f"no-overlap {off.makespan:6.2f}s "
              f"(x{m['overlap_speedup']:.2f}, "
              f"{m['handoff']['prefetch_hits']} prefetch hits) -> "
              + ("bit-exact" if bit else "DIVERGED"))

    results["failures"] = failures
    if n_dev < NODES and os.path.exists(out_path):
        print(f"only {n_dev} device(s) — keeping existing "
              f"{os.path.normpath(out_path)} (run `make lm-exec-smoke` "
              "for the full measurement)")
        return results
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {os.path.normpath(out_path)}")
    return results


if __name__ == "__main__":
    res = run()
    sys.exit(1 if res["failures"] else 0)
