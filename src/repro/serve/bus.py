"""WeightBus: the publication channel from training to serving.

The executor's ``_Handoff`` slots move versioned pytrees BETWEEN
training nodes; the bus is the same idea pointed OUTWARD — every
chapter-train task pushes its freshly-trained layer here
(``PFFExecutor.run(publish=bus)``) and serving replicas pull whole
snapshots out the other side, while training keeps running.

Consistency contract (the reason the bus exists instead of replicas
reading ``executor._states`` directly): a snapshot is exposed only when
EVERY layer (and the softmax head, when the classifier trains one) has
been published at the same version, so a request can never be scored by
a half-published layer set — some layers at chapter c, the rest at
c-1. Each exposed snapshot carries its per-layer version vector; the
replica re-checks it (uniform + monotone) at install, and that check is
the consistency-violation counter the benchmark gates on.

Donation safety: the executor's jitted chapter trainers DONATE their
param buffers, so a published tree would be invalidated by the very
next chapter. ``_publish`` therefore deep-copies every leaf
(``jnp.copy``) before parking it — the bus owns its bits.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp


def _owned(tree):
    """A defensive copy the producing jit can never invalidate."""
    return jax.tree_util.tree_map(jnp.copy, tree)


class WeightBus:
    """Assembles per-layer publications into versioned snapshots.

    ``publish_layer(k, version, piece)`` takes the per-layer dict the
    goodness strategy exports (``good.export([state])`` — ``{"layers":
    [lp]}``, plus ``"local_heads"`` for the §4.4 path); ``publish_head``
    takes the softmax head's params. When version ``v`` is complete the
    full params dict (same structure ``ff_mlp.class_scores`` consumes)
    is parked on the ready list; ``next_snapshot(after)`` hands
    snapshots out IN ORDER, one at a time, so a replica swap-walks every
    version (the per-chapter hot-swap the acceptance gate counts)
    rather than jumping to the newest.
    """

    def __init__(self, n_layers: int, *, has_head: bool = False):
        self.n_layers = int(n_layers)
        self.has_head = bool(has_head)
        self._lock = threading.Lock()
        self._staged: Dict[int, dict] = {}   # version -> {layer: piece} (+head)
        self._ready: List[tuple] = []        # (version, params, vec, wall_t)
        self.stats = {"layers_published": 0, "heads_published": 0,
                      "snapshots_assembled": 0, "snapshots_taken": 0}

    # ---- producer side (called from the training thread) -----------------
    def publish_layer(self, layer: int, version: int, piece: dict):
        piece = _owned(piece)
        with self._lock:
            self._staged.setdefault(version, {})[layer] = piece
            self.stats["layers_published"] += 1
            self._try_assemble(version)

    def publish_head(self, version: int, head_params):
        head_params = _owned(head_params)
        with self._lock:
            self._staged.setdefault(version, {})["head"] = head_params
            self.stats["heads_published"] += 1
            self._try_assemble(version)

    def publish_all(self, version: int, params: dict):
        """Publish a complete params dict in one call — the elastic
        federated aggregate, a restored checkpoint, or a static
        serve-only model."""
        params = _owned(params)
        with self._lock:
            staged = {k: {"layers": [lp]} for k, lp in
                      enumerate(params["layers"])}
            if "local_heads" in params:
                for k, lh in enumerate(params["local_heads"]):
                    staged[k]["local_heads"] = [lh]
            if self.has_head:
                staged["head"] = params["head"]
            self._staged[version] = staged
            self.stats["layers_published"] += self.n_layers
            if self.has_head:
                self.stats["heads_published"] += 1
            self._try_assemble(version)

    def _try_assemble(self, version: int):
        """Lock held. Park a full snapshot iff every piece is in."""
        staged = self._staged.get(version)
        if staged is None:
            return
        if any(k not in staged for k in range(self.n_layers)):
            return
        if self.has_head and "head" not in staged:
            return
        pieces = [staged[k] for k in range(self.n_layers)]
        params = {"layers": [p["layers"][0] for p in pieces]}
        if all("local_heads" in p for p in pieces):
            params["local_heads"] = [p["local_heads"][0] for p in pieces]
        vec = [version] * self.n_layers
        if self.has_head:
            params["head"] = staged["head"]
            vec = vec + [version]
        del self._staged[version]
        self._ready.append((version, params, vec, time.perf_counter()))
        self._ready.sort(key=lambda r: r[0])
        self.stats["snapshots_assembled"] += 1

    # ---- consumer side (called from the serving thread) ------------------
    def next_snapshot(self, after_version: int
                      ) -> Optional[Tuple[int, dict, list, float]]:
        """The OLDEST fully-assembled snapshot newer than
        ``after_version`` as ``(version, params, version_vector,
        published_at)``, or None. Snapshots stay parked (several
        replicas may install the same version)."""
        with self._lock:
            for rec in self._ready:
                if rec[0] > after_version:
                    self.stats["snapshots_taken"] += 1
                    return rec
        return None

    def versions_ready(self) -> List[int]:
        with self._lock:
            return [r[0] for r in self._ready]

    def latest_version(self) -> Optional[int]:
        with self._lock:
            return self._ready[-1][0] if self._ready else None
