"""Trace analysis: critical path over ``pff_dag.deps``, busy/idle,
hand-off attribution, and makespan decomposition.

Consumes the plain trace-dict form (``Tracer.to_dict()``,
``export.load_jsonl(path)``) produced by a traced executor run. The
executor writes one ``task:<kind>`` span per DAG task (attrs ``kind``/
``layer``/``chapter``/``node``) and one closing ``run`` span carrying
the DAG shape (``schedule``/``num_nodes``/``splits``/``n_layers``/
``has_head``/``has_neg``/``strict_neg``), so the analyzer can rebuild
the exact dependency structure from ``repro.core.pff_dag`` — the same
single source of truth the simulator and executor walk — and answer
the questions counters cannot:

* critical path — the heaviest dependency chain through the observed
  task durations. The executor's measured makespan must sit between
  the critical path (can't go faster) and serial execution (the sum of
  task durations, or a measured N=1 run on shared-core hosts):
  ``make trace-smoke`` gates on exactly that (``check_invariants``).
* per-node busy/idle against the run window.
* hand-off attribution — prefetch hits are transfers that completed
  before the consumer needed them (their cost is OFF the critical
  path; the PR 5 "28/28 prefetched" counters, now placed on a
  timeline); cross-node pulls are synchronous waits ON the consumer's
  path.
* makespan decomposition — critical-path seconds, parallel slack
  (work hidden by overlap), and the residual scheduling/dispatch gap.

Durations only mean device time when the trace was recorded with
``Tracer(block_tasks=True)`` (the default); dispatch-only traces still
analyze but the inequality gates are meaningless for them. Retried
tasks contribute the SUM of their attempts' spans (retries serialize
on the owning node).

This module deliberately imports no jax — ``pff_dag`` is pure Python —
so traces can be analyzed offline where jax is absent. The
``--selftest`` CLI (used by the test suite via subprocess, like
``repro.core.pff_exec --matrix``) does lazily import the executor to
record a real N=4 run and check the invariants.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

from repro.core import pff_dag

# event names the executor's hand-off slots emit (see pff_exec._Handoff)
PREFETCH_HIT = "handoff:prefetch_hit"
PREFETCH_ISSUE = "handoff:prefetch_issue"
PULL_CROSS = "handoff:pull_cross"
PULL_LOCAL = "handoff:pull_local"


@dataclasses.dataclass
class TraceAnalysis:
    schedule: str
    num_nodes: int
    splits: int
    n_layers: int
    makespan: float                    # run-span duration (traced run)
    critical_path: List[Tuple[str, int, int]]   # (kind, layer, chapter)
    critical_path_s: float
    sum_task_s: float
    node_busy: Dict[int, float]
    node_idle: Dict[int, float]
    handoff: Dict[str, int]
    decomposition: Dict[str, float]
    counters: Dict[str, float]

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["critical_path"] = [list(t) for t in self.critical_path]
        return d


def _as_dict(trace) -> Dict[str, Any]:
    return trace if isinstance(trace, dict) else trace.to_dict()


def _run_span(spans: List[dict]) -> Optional[dict]:
    runs = [s for s in spans if s["name"] == "run"]
    return runs[-1] if runs else None


def analyze(trace, *, measured_makespan: Optional[float] = None
            ) -> TraceAnalysis:
    """Reconstruct the chapter-task critical path and timing breakdown
    from a traced executor run.

    measured_makespan: a separately measured (untraced, overlap-intact)
    makespan for the decomposition's gap line; defaults to the traced
    run's own window.
    """
    td = _as_dict(trace)
    spans = td.get("spans", [])
    events = td.get("events", [])
    run = _run_span(spans)
    if run is None:
        raise ValueError("trace has no 'run' span — was it recorded by "
                         "PFFExecutor.run(trace=...)?")
    ra = run.get("attrs", {})
    schedule = ra.get("schedule", "?")
    num_nodes = int(ra.get("num_nodes", 1))
    splits = int(ra.get("splits", 0))
    n_layers = int(ra.get("n_layers", 0))
    run_t0, run_t1 = float(run["t0"]), float(run["t1"])
    makespan = run_t1 - run_t0

    # --- per-task durations (sum over retry attempts) -------------------
    dur: Dict[Tuple[str, int, int], float] = {}
    node_of_task: Dict[Tuple[str, int, int], int] = {}
    task_windows: Dict[int, List[Tuple[float, float]]] = {}
    busy: Dict[int, float] = {n: 0.0 for n in range(num_nodes)}
    for s in spans:
        if not s["name"].startswith("task:"):
            continue
        a = s.get("attrs", {})
        key = (a["kind"], int(a["layer"]), int(a["chapter"]))
        d = float(s["t1"]) - float(s["t0"])
        dur[key] = dur.get(key, 0.0) + d
        node = int(a.get("node", 0))
        node_of_task[key] = node
        busy[node] = busy.get(node, 0.0) + d
        task_windows.setdefault(node, []).append(
            (float(s["t0"]), float(s["t1"])))
    if not dur:
        raise ValueError("trace has no task:* spans")
    sum_task_s = sum(dur.values())
    idle = {n: max(makespan - b, 0.0) for n, b in busy.items()}

    # --- longest path over pff_dag.deps ---------------------------------
    # elastic federated runs execute whole rounds as single tasks
    # (kind="round"); their dependency structure is a plain chain.
    cp_tasks, cp_len = _critical_path(
        dur, splits=splits, n_layers=n_layers,
        has_head=bool(ra.get("has_head", False)),
        has_neg=bool(ra.get("has_neg", False)),
        strict_neg=bool(ra.get("strict_neg", False)))

    # --- hand-off attribution -------------------------------------------
    cp_set = set(cp_tasks)
    counts = {PREFETCH_HIT: 0, PREFETCH_ISSUE: 0, PULL_CROSS: 0,
              PULL_LOCAL: 0}
    hits_inside_task = 0
    cross_on_cp = 0
    for e in events:
        if e["name"] not in counts:
            continue
        counts[e["name"]] += 1
        node = int(e.get("attrs", {}).get("node", -1))
        inside = any(t0 <= float(e["t"]) <= t1
                     for t0, t1 in task_windows.get(node, ()))
        if e["name"] == PREFETCH_HIT and inside:
            hits_inside_task += 1
        if e["name"] == PULL_CROSS:
            # a miss stalls whichever task consumed it; if that task is
            # on the critical path the wait is pure makespan
            key = _task_at(e, task_windows, node, spans)
            if key is not None and key in cp_set:
                cross_on_cp += 1
    handoff = {
        "prefetch_issued": counts[PREFETCH_ISSUE],
        "prefetch_hits": counts[PREFETCH_HIT],
        "pulls_cross": counts[PULL_CROSS],
        "pulls_local": counts[PULL_LOCAL],
        # a hit == the transfer landed before the consumer asked: its
        # cost is off the critical path by construction
        "off_critical_path": counts[PREFETCH_HIT],
        "on_critical_path": cross_on_cp,
        "hits_inside_task_spans": hits_inside_task,
    }

    m = measured_makespan if measured_makespan is not None else makespan
    decomposition = {
        "critical_path_s": cp_len,
        "parallel_slack_s": max(sum_task_s - cp_len, 0.0),
        "makespan_gap_s": m - cp_len,
        "measured_makespan_s": m,
    }
    return TraceAnalysis(
        schedule=schedule, num_nodes=num_nodes, splits=splits,
        n_layers=n_layers, makespan=makespan,
        critical_path=list(cp_tasks), critical_path_s=cp_len,
        sum_task_s=sum_task_s, node_busy=busy, node_idle=idle,
        handoff=handoff, decomposition=decomposition,
        counters=dict(td.get("counters", {})))


def _task_at(event, task_windows, node, spans
             ) -> Optional[Tuple[str, int, int]]:
    """The (kind, layer, chapter) of the task span enclosing an event
    on its node, if any."""
    t = float(event["t"])
    for s in spans:
        if not s["name"].startswith("task:"):
            continue
        a = s.get("attrs", {})
        if int(a.get("node", -2)) == node and \
                float(s["t0"]) <= t <= float(s["t1"]):
            return (a["kind"], int(a["layer"]), int(a["chapter"]))
    return None


def _critical_path(dur: Dict[Tuple[str, int, int], float], *,
                   splits: int, n_layers: int, has_head: bool,
                   has_neg: bool, strict_neg: bool
                   ) -> Tuple[List[Tuple[str, int, int]], float]:
    """Longest weighted chain through the observed tasks using
    ``pff_dag.deps`` edges (restricted to tasks actually in the trace —
    a resumed run's trace only covers the replay frontier)."""
    # canonical order is a valid topological order; "round" tasks
    # (elastic federated) form their own per-chapter chain
    order: List[Tuple[str, int, int]] = []
    if any(k == "round" for k, _, _ in dur):
        order = sorted((key for key in dur if key[0] == "round"),
                       key=lambda key: key[2])
        edges = {key: ([("round", -1, key[2] - 1)] if key[2] > 0 else [])
                 for key in order}
    else:
        edges = {}
        for t in pff_dag.build_tasks(n_layers, splits, has_head=has_head,
                                     has_neg=has_neg):
            key = (t.kind, t.layer, t.chapter)
            if key not in dur:
                continue
            order.append(key)
            edges[key] = [
                (d.kind, d.layer, d.chapter)
                for d in pff_dag.deps(t, n_layers, has_head=has_head,
                                      has_neg=has_neg,
                                      strict_neg=strict_neg)
                if (d.kind, d.layer, d.chapter) in dur]
    dist: Dict[Tuple[str, int, int], float] = {}
    pred: Dict[Tuple[str, int, int], Optional[Tuple[str, int, int]]] = {}
    for key in order:
        best, bp = 0.0, None
        for d in edges[key]:
            if dist[d] > best:
                best, bp = dist[d], d
        dist[key] = best + dur[key]
        pred[key] = bp
    end = max(dist, key=lambda key: dist[key])
    path: List[Tuple[str, int, int]] = []
    cur: Optional[Tuple[str, int, int]] = end
    while cur is not None:
        path.append(cur)
        cur = pred[cur]
    path.reverse()
    return path, dist[end]


def check_invariants(analysis: TraceAnalysis, measured_makespan: float,
                     *, serial_makespan: Optional[float] = None,
                     slack: float = 1.02) -> List[str]:
    """The trace-smoke gate: critical path <= measured makespan <=
    serial execution, with a small tolerance for clock jitter between
    the traced and the timed run.

    The serial bound is the sum of task durations by default — exact
    when each faked device owns a real core. On a shared-core container
    the parallel run contends for cores the blocked per-task
    measurements had to themselves, and the schedule window also pays
    driver/hand-off time outside any task span, so callers there pass
    ``serial_makespan`` (a measured N=1 run under the SAME contention,
    the ``benchmarks/pff_exec.py`` convention) and the gate takes the
    larger of the two bounds.
    """
    fails = []
    if analysis.critical_path_s > measured_makespan * slack:
        fails.append(
            f"critical path {analysis.critical_path_s:.3f}s exceeds "
            f"measured makespan {measured_makespan:.3f}s — task spans "
            f"are not real device time?")
    bound = max(analysis.sum_task_s, serial_makespan or 0.0)
    if measured_makespan > bound * slack:
        fails.append(
            f"measured makespan {measured_makespan:.3f}s exceeds the "
            f"serial bound {bound:.3f}s (sum of task durations "
            f"{analysis.sum_task_s:.3f}s"
            + (f", measured serial run {serial_makespan:.3f}s"
               if serial_makespan else "")
            + ") — schedule ran slower than serial execution")
    return fails


# ---------------------------------------------------------------------------
# selftest: record a real N=4 all_layers run and check the invariants
# (subprocess entry for tests; needs XLA_FLAGS host-device faking like
#  `python -m repro.core.pff_exec --matrix`)
# ---------------------------------------------------------------------------

def _selftest() -> int:                              # pragma: no cover
    import jax

    from repro import data as data_lib
    from repro.configs.ff_mlp import FFMLPConfig
    from repro.core import pff_exec
    from repro.obs import trace as trace_lib

    if jax.device_count() < 4:
        print("obs.analyze selftest needs >= 4 devices (set XLA_FLAGS="
              "--xla_force_host_platform_device_count=4)")
        return 1
    cfg = FFMLPConfig(layer_sizes=(784, 32, 32, 32), epochs=8, splits=4,
                      neg_mode="random", classifier="goodness",
                      goodness_fn="sumsq", batch_size=64, seed=0)
    task = data_lib.mnist_like(n_train=512, n_test=128)
    ex = pff_exec.PFFExecutor(cfg, task, "all_layers", 4)
    ex.run()                                      # compile warm-up
    tr = trace_lib.Tracer()
    traced = ex.run(trace=tr)
    # best-of-3: this config runs in tens of ms, where single-shot wall
    # clocks carry ~10% scheduler jitter
    timed = min((ex.run() for _ in range(3)),
                key=lambda r: r.makespan)         # warm, overlap intact
    ex1 = pff_exec.PFFExecutor(cfg, task, "sequential", 1)
    ex1.run()                                     # compile warm-up
    serial = min((ex1.run() for _ in range(3)),
                 key=lambda r: r.makespan)        # measured serial bound
    a = analyze(tr, measured_makespan=timed.makespan)
    # wide slack: at this tens-of-ms scale on a shared-core container
    # the N=4 schedule's dispatch overhead can legitimately push it past
    # serial; the selftest asserts the trace->analyze->gate plumbing.
    # The tight 1.02 gate runs at real scale in benchmarks/trace.py.
    fails = check_invariants(a, timed.makespan,
                             serial_makespan=serial.makespan, slack=1.5)
    if traced.handoff is not None and \
            a.handoff["prefetch_hits"] != traced.handoff["prefetch_hits"]:
        fails.append(f"trace prefetch_hit events "
                     f"{a.handoff['prefetch_hits']} != executor counter "
                     f"{traced.handoff['prefetch_hits']}")
    print(f"obs.analyze selftest: cp={a.critical_path_s:.3f}s "
          f"makespan={timed.makespan:.3f}s sum={a.sum_task_s:.3f}s "
          f"serial={serial.makespan:.3f}s "
          f"busy={ {n: round(b, 3) for n, b in a.node_busy.items()} } "
          f"handoff={a.handoff}")
    for f in fails:
        print(f"FAIL: {f}")
    return 1 if fails else 0


if __name__ == "__main__":                           # pragma: no cover
    import sys
    sys.exit(_selftest())
