"""FF training hot-loop benchmark: seed-style unfused steps vs the
stacked single-matmul path vs the fused Pallas custom_vjp kernel.

Three variants of the chapter step are timed across the paper's
[784 -> 2000 -> 2000 -> 2000 -> 2000] layer shapes:

  seed_unfused — the pre-PR hot loop: two separate (B, K) matmuls per
                 step (pos + neg) under jax.grad (4 matmul dispatches
                 per step including backward).
  ref_stacked  — the current loop with kernel_impl=ref: ONE (2B, K)
                 stacked matmul per direction (2 dispatches per step).
  pallas_fused — the current loop with kernel_impl=pallas: the fused
                 matmul -> ReLU -> goodness Pallas kernel + the fused
                 backward kernel (interpret mode on this CPU container,
                 Mosaic on a real TPU).

Matmul dispatch counts are measured from the jaxprs (dot_general eqns in
the gradient computation), not asserted by hand. Results land in
``BENCH_ff_hotloop.json`` at the repo root so every future PR has a
trajectory to beat; gradient max-err vs the oracle is included so
``benchmarks/run.py`` can fail loudly on correctness regressions.

NOTE: pallas timings on this container are interpret-mode and NOT
indicative of TPU wall-clock; the load-bearing CPU numbers are
seed_unfused vs ref_stacked (dispatch halving) and the dispatch counts.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from repro import optim
from repro.core import ff, ff_mlp

PAPER_SIZES = (784, 2000, 2000, 2000, 2000)
THETA = 2.0


# ---------------------------------------------------------------------------
# Seed-style (pre-PR) chapter step: two unfused matmuls per step
# ---------------------------------------------------------------------------

def _seed_layer_loss(lp, xb_pos, xb_neg, theta, peer_w):
    y_pos = jax.nn.relu(xb_pos @ lp["w"] + lp["b"])
    y_neg = jax.nn.relu(xb_neg @ lp["w"] + lp["b"])
    loss = ff.ff_loss(ff.mean_goodness(y_pos), ff.mean_goodness(y_neg),
                      theta)
    if peer_w:
        loss = loss + peer_w * ff.peer_norm_loss(y_pos)
    return loss


def _make_seed_chapter(batch, epochs, theta):
    @jax.jit
    def run(lp, opt, x_pos, x_neg, lrs, key):
        n = x_pos.shape[0]
        n_batches = n // batch

        def epoch_body(carry, ei):
            lp, opt, step = carry
            perm = jax.random.permutation(jax.random.fold_in(key, ei), n)

            def batch_body(carry, bi):
                lp, opt, step = carry
                idx = jax.lax.dynamic_slice_in_dim(perm, bi * batch, batch)
                g = jax.grad(_seed_layer_loss)(lp, x_pos[idx], x_neg[idx],
                                               theta, 0.0)
                step = step + 1
                lp, opt = optim.adam_update(lp, g, opt, lr=lrs[ei],
                                            step=step)
                return (lp, opt, step), None

            (lp, opt, step), _ = jax.lax.scan(
                batch_body, (lp, opt, step), jnp.arange(n_batches))
            return (lp, opt, step), None

        (lp, opt, _), _ = jax.lax.scan(
            epoch_body, (lp, opt, jnp.zeros((), jnp.int32)),
            jnp.arange(epochs))
        return lp, opt
    return run


# ---------------------------------------------------------------------------
# Jaxpr matmul-dispatch counter
# ---------------------------------------------------------------------------

def _count_eqns(jaxpr, names, skip=("pallas_call",)):
    """Occurrences of the named primitives, recursing into sub-jaxprs
    but NOT into the ``skip`` call primitives (ops fused inside a Pallas
    kernel are one dispatch, not separate XLA ops)."""
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name in skip:
            continue
        if eqn.primitive.name in names:
            n += 1
        for v in eqn.params.values():
            if isinstance(v, jax.core.ClosedJaxpr):
                n += _count_eqns(v.jaxpr, names, skip)
            elif isinstance(v, jax.core.Jaxpr):
                n += _count_eqns(v, names, skip)
            elif isinstance(v, (tuple, list)):
                for vv in v:
                    if isinstance(vv, jax.core.ClosedJaxpr):
                        n += _count_eqns(vv.jaxpr, names, skip)
                    elif isinstance(vv, jax.core.Jaxpr):
                        n += _count_eqns(vv, names, skip)
    return n


def _count_dots(jaxpr):
    return _count_eqns(jaxpr, ("dot_general",), skip=())


def matmul_dispatches_per_step(K, N, batch):
    """dot_general count in ONE gradient step, seed vs stacked-ref."""
    lp = {"w": jnp.zeros((K, N)), "b": jnp.zeros((N,))}
    xp = jnp.zeros((batch, K))
    xb = jnp.zeros((2 * batch, K))
    seed = _count_dots(jax.make_jaxpr(
        lambda lp, a, b: jax.grad(_seed_layer_loss)(lp, a, b, THETA, 0.0)
    )(lp, xp, xp).jaxpr)
    stacked = _count_dots(jax.make_jaxpr(
        lambda lp, x: jax.grad(ff_mlp._ff_layer_loss)(lp, x, THETA, 0.0,
                                                      "ref")
    )(lp, xb).jaxpr)
    return seed, stacked


def handoff_norm_divide_ops(K, N, batch):
    """XLA ``div`` ops in the inter-layer hand-off (``ff_mlp.fwd_norm``)
    jaxpr, per kernel path — ops fused into the Pallas kernel body do
    not count (they are part of the one ``ff_dense`` dispatch). The ref
    oracle keeps its separate divide by construction; the fused path
    must show ZERO, i.e. the norm divide lives in the kernel epilogue —
    ``benchmarks/run.py`` fails loudly otherwise."""
    lp = {"w": jnp.zeros((K, N)), "b": jnp.zeros((N,))}
    x = jnp.zeros((batch, K))
    out = {}
    for impl in ("ref", "pallas"):
        jx = jax.make_jaxpr(
            lambda lp, x, impl=impl: ff_mlp.fwd_norm(lp, x, impl=impl)
        )(lp, x)
        name = "ref_stacked" if impl == "ref" else "pallas_fused"
        out[name] = _count_eqns(jx.jaxpr, ("div",))
    return out


# ---------------------------------------------------------------------------
# Timing
# ---------------------------------------------------------------------------

def _time_chapter(run_fn, make_args, repeats):
    # warmup/compile (donation-safe: fresh args); block so pending
    # warm-up device work cannot leak into the first timed repeat
    jax.block_until_ready(run_fn(*make_args()))
    best = float("inf")
    for _ in range(repeats):
        args = make_args()
        t0 = time.perf_counter()
        out = run_fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def bench_layer(key, K, N, *, n, batch, epochs, repeats, with_pallas=True):
    kx, kn, kw, kt = jax.random.split(key, 4)
    x_pos = jax.random.normal(kx, (n, K), jnp.float32)
    x_neg = jax.random.normal(kn, (n, K), jnp.float32)
    lrs = jnp.full((epochs,), 0.01, jnp.float32)
    steps = (n // batch) * epochs

    def fresh():
        lp = {"w": jax.random.normal(kw, (K, N), jnp.float32) * K ** -0.5,
              "b": jnp.zeros((N,), jnp.float32)}
        return lp, optim.adam_init(lp)

    out = {}
    seed_run = _make_seed_chapter(batch, epochs, THETA)
    t = _time_chapter(
        seed_run, lambda: (*fresh(), x_pos, x_neg, lrs, kt), repeats)
    out["seed_unfused"] = {"steps_per_sec": steps / t,
                           "examples_per_sec": steps * batch / t}

    impls = ("ref", "pallas") if with_pallas else ("ref",)
    for impl in impls:
        def run(lp, opt):
            return ff_mlp.train_layer_chapter(
                lp, opt, x_pos, x_neg, lrs, kt, batch=batch,
                epochs=epochs, theta=THETA, peer_w=0.0, impl=impl)
        t = _time_chapter(run, fresh, repeats)
        name = "ref_stacked" if impl == "ref" else "pallas_fused"
        out[name] = {"steps_per_sec": steps / t,
                     "examples_per_sec": steps * batch / t}

    base = out["seed_unfused"]["steps_per_sec"]
    for name in ("ref_stacked", "pallas_fused"):
        if name in out:
            out[name]["speedup_vs_seed"] = out[name]["steps_per_sec"] / base
    return out


def grad_max_err(key, K, N, batch):
    """Fused-kernel gradient vs the jax.grad-of-oracle gradient."""
    kx, kw = jax.random.split(key)
    xb = jax.random.normal(kx, (2 * batch, K), jnp.float32)
    lp = {"w": jax.random.normal(kw, (K, N), jnp.float32) * K ** -0.5,
          "b": jnp.full((N,), 0.1, jnp.float32)}
    gp = jax.grad(ff_mlp._ff_layer_loss)(lp, xb, THETA, 0.1, "pallas")
    gr = jax.grad(ff_mlp._ff_layer_loss)(lp, xb, THETA, 0.1, "ref")
    return max(float(jnp.abs(gp[k] - gr[k]).max()) for k in ("w", "b"))


def run(quick=True, out_path=None):
    """Returns the result dict (also written to BENCH_ff_hotloop.json)."""
    if out_path is None:
        out_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                os.pardir, "BENCH_ff_hotloop.json")
    key = jax.random.PRNGKey(0)
    n, batch, epochs, repeats = (1024, 64, 1, 3) if quick \
        else (4096, 64, 2, 5)

    seed_d, stacked_d = matmul_dispatches_per_step(
        PAPER_SIZES[0], PAPER_SIZES[1], batch)
    print(f"matmul dispatches per grad step: seed={seed_d} "
          f"stacked={stacked_d}")
    norm_divs = handoff_norm_divide_ops(PAPER_SIZES[0], PAPER_SIZES[1],
                                        batch)
    print(f"norm-divide ops in the inter-layer hand-off jaxpr: "
          f"ref={norm_divs['ref_stacked']} "
          f"pallas={norm_divs['pallas_fused']} (0 = fused into the "
          f"kernel epilogue)")

    results = {
        "config": {"n_train": n, "batch": batch, "epochs_per_chapter":
                   epochs, "layer_sizes": list(PAPER_SIZES),
                   "backend": jax.default_backend(),
                   "pallas_interpret": jax.default_backend() != "tpu"},
        "matmul_dispatches_per_step": {"seed_unfused": seed_d,
                                       "stacked": stacked_d},
        "handoff_norm_divide_ops": norm_divs,
        "layers": [],
        "note": ("pallas timings are interpret-mode on non-TPU backends; "
                 "dispatch counts + grad_max_err are the load-insensitive "
                 "signals, steps/sec varies with container load"),
    }

    worst_err = 0.0
    cache = {}
    for i in range(len(PAPER_SIZES) - 1):
        K, N = PAPER_SIZES[i], PAPER_SIZES[i + 1]
        if (K, N) not in cache:
            err = grad_max_err(jax.random.fold_in(key, i), K, N, batch)
            timings = bench_layer(jax.random.fold_in(key, 100 + i), K, N,
                                  n=n, batch=batch, epochs=epochs,
                                  repeats=repeats)
            cache[(K, N)] = (timings, err)
        timings, err = cache[(K, N)]
        worst_err = max(worst_err, err)
        entry = {"layer": i, "K": K, "N": N, "grad_max_err_vs_oracle": err}
        entry.update(timings)
        results["layers"].append(entry)
        sps = {k: v["steps_per_sec"] for k, v in timings.items()}
        print(f"layer {i} ({K}->{N}): " + "  ".join(
            f"{k}={v:.1f} steps/s" for k, v in sps.items())
            + f"  grad_err={err:.2e}")

    results["max_grad_err"] = worst_err
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {os.path.normpath(out_path)} "
          f"(max grad err {worst_err:.2e})")
    return results
