"""Measured vs simulator-predicted PFF: the real executor on host devices.

The repo's central claim used to be SIMULATED only — ``core/pff.py``
times the canonical chapter schedule and replays the timings through an
event-driven simulator. This benchmark runs the same schedules for REAL
through ``core/pff_exec.py`` on an actual ``jax.devices()`` set and
writes measured makespan/speedup/utilization NEXT TO the simulator's
prediction into ``BENCH_pff_exec.json`` for N ∈ {1, 2, 4} nodes
(all_layers, plus single_layer and federated at N=4).

Protocol per row:
  1. a profiled executor run (blocks after every task) — doubles as the
     per-device compile warm-up AND yields per-node busy-seconds,
  2. a non-profiled run on warm caches — its wall-clock from first
     dispatch to last-weight-ready is the measured makespan,
  3. for N > 1, a second warm run with ``overlap=False`` — the
     serialize-on-demand hand-off baseline (double-buffered vs on-demand
     makespan, plus prefetched vs critical-path transfer counts),
  4. the simulator's prediction replaying the canonical trainer's
     task timings under the same node assignment.
Measured speedup = measured sequential (N=1) makespan / row makespan.
Utilization_est = profiled busy-seconds / (N * measured makespan).

The all_layers rows double as a correctness gate: the executor's final
weights must be BIT-IDENTICAL to the sequential trainer's
(``benchmarks/run.py`` exits non-zero otherwise).

Caveat for CPU containers: the faked host devices share the machine's
cores (this box has very few), so measured speedup is bounded by the
core budget, not by the schedule — the honest comparison is measured
makespan vs simulator prediction under the SAME contention. On real
multi-device hardware the simulator's speedup is the one to approach.
Needs >= 4 devices: export XLA_FLAGS=--xla_force_host_platform_device_count=4
before jax is imported (``make pff-exec-smoke`` does; this module also
sets it when imported before jax).
"""
from __future__ import annotations

import json
import os
import sys

if "jax" not in sys.modules:                       # pragma: no cover
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax

from repro import api, data as data_lib
from repro.configs.ff_mlp import FFMLPConfig
from repro.core import pff, pff_exec

NODE_COUNTS = (1, 2, 4)


def _measure(cfg, task, schedule, num_nodes, devices):
    ex = pff_exec.PFFExecutor(cfg, task, schedule, num_nodes,
                              devices=devices)
    prof = ex.run(profile=True)       # compile warm-up + busy estimate
    timed = ex.run(profile=False)     # warm-cache makespan
    # busy estimate from the profiled run, but with each task's duration
    # replaced by its (kind, layer) median — the same compile-outlier
    # smoothing simulate_schedule applies to the canonical records (the
    # profiled run is cold, so raw sums overstate busy time).
    durs = pff.task_durations(prof.records)
    busy = sum(durs[(r.kind, r.layer)] for r in prof.records)
    measured = {
        "makespan_s": timed.makespan,
        "busy_s_profiled": busy,
        # clamped: blocked per-task profiling pays a host sync per task
        # that the pipelined run does not, so the raw ratio can exceed
        # 1 on a contended CPU host — busy_s_profiled keeps the raw sum
        "utilization_est": min(1.0, busy / (num_nodes * timed.makespan))
        if timed.makespan else 1.0,
        "test_acc": timed.test_acc,
        "handoff": timed.handoff,
    }
    if num_nodes > 1:
        # A/B: the serialize-on-demand hand-off (double-buffering off).
        # One warm run is enough — the jit caches are shared with the
        # overlap executor (identical shapes/executables), so the only
        # difference on the clock is WHEN transfers are issued.
        off = pff_exec.PFFExecutor(cfg, task, schedule, num_nodes,
                                   devices=devices, overlap=False
                                   ).run(profile=False)
        measured["makespan_s_no_overlap"] = off.makespan
        measured["handoff_no_overlap"] = off.handoff
        measured["overlap_speedup"] = (off.makespan / timed.makespan
                                       if timed.makespan else 1.0)
    return timed, measured


def run(quick=True, out_path=None):
    if out_path is None:
        out_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                os.pardir, "BENCH_pff_exec.json")
    n_train, splits, epochs, sizes = (
        (1000, 8, 8, (784, 256, 256, 256, 256)) if quick
        else (4000, 16, 16, (784, 512, 512, 512, 512)))
    # n_train deliberately NOT divisible by batch: the tail-batch path
    # stays exercised in every CI run.
    cfg = FFMLPConfig(layer_sizes=sizes, epochs=epochs, splits=splits,
                      neg_mode="random", classifier="goodness",
                      batch_size=64, seed=0)
    task = data_lib.mnist_like(n_train=n_train, n_test=500)
    devices = jax.devices()
    n_dev = len(devices)
    print(f"devices: {n_dev} x {devices[0].platform}")

    # canonical sequential trainer: weight-stream oracle + task timings
    ref = api.fit(cfg, task, backend="sequential")
    print(f"sequential trainer: test acc {ref.test_acc:.4f}")

    results = {
        "config": {"n_train": n_train, "splits": splits, "epochs": epochs,
                   "layer_sizes": list(sizes),
                   "batch_size": cfg.batch_size,
                   "backend": jax.default_backend(), "devices": n_dev,
                   "cpu_count": os.cpu_count()},
        "note": ("measured speedup on a CPU container is bounded by the "
                 "host core budget shared across the faked devices; the "
                 "simulator predicts the schedule's own ceiling. "
                 "utilization_est divides profiled (contention-free) "
                 "busy-seconds by the overlapped makespan."),
        "rows": [],
    }
    failures = []

    seq_measured = None
    rows = [("all_layers", n) for n in NODE_COUNTS]
    rows += [("single_layer", 4), ("federated", 4)]
    for schedule, n in rows:
        sim = pff.simulate_schedule(ref.records, schedule, n)
        row = {"schedule": schedule, "nodes": n,
               "sim": {"makespan_s": sim.makespan, "speedup": sim.speedup,
                       "utilization": sim.utilization}}
        if n > n_dev:
            row["measured"] = None
            row["note"] = (f"needs {n} devices, found {n_dev} — set "
                           "XLA_FLAGS=--xla_force_host_platform_device_"
                           f"count={n} (see make pff-exec-smoke)")
        else:
            timed, measured = _measure(
                cfg, task, "sequential" if n == 1 else schedule, n,
                devices)
            if n == 1:
                seq_measured = measured["makespan_s"]
            if seq_measured:
                measured["speedup"] = seq_measured / measured["makespan_s"]
            row["measured"] = measured
            if schedule == "federated":
                row["note"] = ("federated trains 1/N-size node-local "
                               "shards, so measured tasks are smaller "
                               "than the full-dataset timings the "
                               "simulator replays — measured speedup "
                               "includes that data reduction, sim "
                               "speedup does not")
            if schedule == "all_layers":
                bit = pff_exec.params_bit_equal(ref.params, timed.params)
                row["weights_bit_exact_vs_sequential"] = bit
                if not bit:
                    failures.append(f"{schedule} N={n}: executor weight "
                                    "stream diverged from the sequential "
                                    "trainer")
        results["rows"].append(row)
        m = row["measured"]
        overlap_note = ""
        if m and "makespan_s_no_overlap" in m:
            hits = m["handoff"]["prefetch_hits"]
            cross = m["handoff_no_overlap"]["pulls_cross"]
            off_s = m["makespan_s_no_overlap"]
            overlap_note = (f" | no-overlap {off_s:6.2f}s "
                            f"(x{m['overlap_speedup']:.2f}, "
                            f"{hits}/{cross} cross-node transfers "
                            f"prefetched)")
        print(f"{schedule:>13} N={n}: sim speedup {sim.speedup:5.2f}x "
              f"util {sim.utilization:.2f}" +
              (f" | measured makespan {m['makespan_s']:6.2f}s "
               f"speedup {m.get('speedup', 1.0):5.2f}x "
               f"util_est {m['utilization_est']:.2f}"
               if m else " | not measured (too few devices)")
              + overlap_note)

    results["failures"] = failures
    if n_dev < max(NODE_COUNTS) and os.path.exists(out_path):
        # degraded run (too few devices): keep the committed multi-node
        # baseline instead of clobbering it with unmeasured rows
        print(f"only {n_dev} device(s) — keeping existing "
              f"{os.path.normpath(out_path)} (run `make pff-exec-smoke` "
              "or set XLA_FLAGS for the full measurement)")
        return results
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {os.path.normpath(out_path)}")
    return results
