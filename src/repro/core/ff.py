"""Forward-Forward primitives (Hinton 2022, as used by the PFF paper).

Goodness, FF losses, label embedding for image tasks, negative-sample
strategies (AdaptiveNEG / FixedNEG / RandomNEG), negative-sequence
corruption for LM tasks, and both prediction modes (Goodness / Softmax).

Image samples follow the paper exactly: the first ``num_classes`` pixels
of the flattened image carry a one-hot label overlay (positive = true
label, negative = a wrong label, neutral = uniform 1/C for Softmax
prediction).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Goodness + loss
# ---------------------------------------------------------------------------

def goodness(y):
    """Sum of squared activities over the feature axis (paper Eq. 1)."""
    return jnp.sum(jnp.square(y.astype(jnp.float32)), axis=-1)


def mean_goodness(y):
    """Dimension-normalized goodness — scale-free across layer widths.

    Used for the transformer FF losses so a single theta works for every
    d_model; the MLP path uses the paper's raw sum (theta there follows
    Hinton's convention).
    """
    return jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1)


def ff_loss(g_pos, g_neg, theta):
    """Paper Eq. 1: -log sigma(g_pos - theta) - log sigma(theta - g_neg).

    softplus(x) = -log sigma(-x); mean over the batch.
    """
    return (jnp.mean(jax.nn.softplus(theta - g_pos)) +
            jnp.mean(jax.nn.softplus(g_neg - theta)))


def ff_loss_masked(g, is_pos, theta):
    """Mixed pos/neg batch. g: (B, ...), is_pos: (B,) in {0., 1.}."""
    while is_pos.ndim < g.ndim:
        is_pos = is_pos[..., None]
    per = jnp.where(is_pos > 0.5, jax.nn.softplus(theta - g),
                    jax.nn.softplus(g - theta))
    return jnp.mean(per)


def peer_norm_loss(y):
    """Hinton's peer normalization: push mean activities toward their
    average (prevents dead/hyperactive units). y: (B, D) post-ReLU."""
    mean_act = jnp.mean(y.astype(jnp.float32), axis=0)      # (D,)
    target = jnp.mean(mean_act)
    return jnp.mean(jnp.square(mean_act - target))


# ---------------------------------------------------------------------------
# Label overlay (image tasks — paper's MNIST/CIFAR encoding)
# ---------------------------------------------------------------------------

def overlay_label(x, label, num_classes):
    """x: (B, D) in [0,1]; label: (B,) int or (B, C) float distribution."""
    if label.ndim == 1:
        lab = jax.nn.one_hot(label, num_classes, dtype=x.dtype)
    else:
        lab = label.astype(x.dtype)
    return jnp.concatenate([lab, x[:, num_classes:]], axis=1)


def overlay_neutral(x, num_classes):
    lab = jnp.full((x.shape[0], num_classes), 1.0 / num_classes, x.dtype)
    return jnp.concatenate([lab, x[:, num_classes:]], axis=1)


# ---------------------------------------------------------------------------
# Negative-label strategies (image tasks)
# ---------------------------------------------------------------------------

def random_wrong_labels(key, labels, num_classes):
    """Uniform over the C-1 wrong labels (RandomNEG / FixedNEG)."""
    shift = jax.random.randint(key, labels.shape, 1, num_classes)
    return (labels + shift) % num_classes


def adaptive_wrong_labels(class_scores, labels, key=None, temp=1.0):
    """AdaptiveNEG: pick a *confusable* wrong label from the model's
    per-class scores (paper: 'most predicted incorrect label').

    class_scores: (B, C) higher = more predicted. The true label is
    masked out; with key=None takes the argmax (deterministic), else
    samples proportionally to z-scored goodness (Hinton's recipe —
    deterministic argmax collapses label diversity: every class-c image
    gets the same wrong label forever, and the network learns label-
    frequency shortcuts instead of image-label agreement).
    """
    B, C = class_scores.shape
    true_hot = jax.nn.one_hot(labels, C, dtype=bool)
    masked = jnp.where(true_hot, -jnp.inf, class_scores)
    if key is None:
        return jnp.argmax(masked, axis=1).astype(labels.dtype)
    # z-score over the WRONG-label columns only: including the masked
    # true-label column would bias mu/sd by the true label's magnitude
    # (typically the row maximum), flattening the sampling distribution
    # exactly where the model is confident.
    wrong = jnp.where(true_hot, 0.0, class_scores)
    mu = jnp.sum(wrong, axis=1, keepdims=True) / (C - 1)
    var = jnp.sum(jnp.where(true_hot, 0.0, jnp.square(class_scores - mu)),
                  axis=1, keepdims=True) / (C - 1)
    sd = jnp.sqrt(var) + 1e-6
    z = jnp.where(jnp.isfinite(masked), (masked - mu) / sd, -jnp.inf)
    return jax.random.categorical(key, z / temp, axis=1).astype(
        labels.dtype)


# ---------------------------------------------------------------------------
# Negative sequences (LM tasks) — the paper's wrong-label overlay,
# adapted to tokens: hybrid sequences spliced from two real sequences
# (Hinton's hybrid-image recipe) + random token resampling.
# ---------------------------------------------------------------------------

def corrupt_tokens(key, tokens, vocab, frac=0.3, span=16):
    """Hybrid negatives: splice spans from a batch-permuted copy, then
    resample a small fraction of tokens uniformly.

    tokens: (B, S) int32. Returns (B, S) int32 negatives.
    """
    B, S = tokens.shape
    k1, k2, k3, k4 = jax.random.split(key, 4)
    donor = tokens[jax.random.permutation(k1, B)]
    # span mask: coarse boolean grid upsampled to S (ceil-repeat + crop)
    n_spans = max(S // span, 1)
    coarse = jax.random.bernoulli(k2, frac, (B, n_spans))
    rep = -(-S // n_spans)
    mask = jnp.repeat(coarse, rep, axis=1)[:, :S]
    out = jnp.where(mask, donor, tokens)
    # sprinkle uniform-random tokens (keeps negatives off-manifold)
    resample = jax.random.bernoulli(k3, 0.05, (B, S))
    rand_tok = jax.random.randint(k4, (B, S), 0, vocab)
    return jnp.where(resample, rand_tok, out)


def adaptive_corrupt_tokens(key, tokens, logits, frac=0.3, span=16):
    """AdaptiveNEG for LM: fill corrupted spans with tokens sampled from
    the model's own predictive distribution (self-generated negatives —
    the closest analogue of 'most predicted incorrect label').

    logits: (B, S, V) from a no-grad forward with the current weights.
    """
    B, S = tokens.shape
    k1, k2 = jax.random.split(key)
    model_tok = jax.random.categorical(k1, logits, axis=-1)   # (B, S)
    # shift: logits at position t predict t+1
    model_tok = jnp.concatenate([tokens[:, :1], model_tok[:, :-1]], axis=1)
    n_spans = max(S // span, 1)
    coarse = jax.random.bernoulli(k2, frac, (B, n_spans))
    rep = -(-S // n_spans)
    mask = jnp.repeat(coarse, rep, axis=1)[:, :S]
    return jnp.where(mask, model_tok, tokens)


# ---------------------------------------------------------------------------
# Prediction (image tasks)
# ---------------------------------------------------------------------------

def goodness_predict(layer_goodness_fn, x, num_classes):
    """Paper's Goodness mode: overlay each label, accumulate goodness of
    all-but-first layers, argmax.

    layer_goodness_fn(x_overlaid) -> (B,) accumulated goodness.
    """
    def per_class(c):
        lab = jnp.full((x.shape[0],), c, jnp.int32)
        return layer_goodness_fn(overlay_label(x, lab, num_classes))

    scores = jax.vmap(per_class)(jnp.arange(num_classes))     # (C, B)
    return jnp.argmax(scores.T, axis=1), scores.T
