"""Synthetic data pipelines (container is offline — no real MNIST/CIFAR).

Image tasks: deterministic class-prototype generators. Each class has a
smooth random prototype; samples are ``clip(proto + noise)``. ``mnist_like``
is close to linearly separable (98%+ reachable, like MNIST); ``cifar_like``
uses heavier noise + class-overlapping prototypes (much harder, mimicking
the paper's CIFAR-10 gap).

LM task: a random first-order Markov chain over the vocabulary with a
Zipf-ish stationary marginal — gives next-token structure a model can
learn (CE well below uniform) while being fully deterministic.

All generators are pure functions of (seed, split) — every node in a
distributed/federated run regenerates its shard without communication.
"""
from __future__ import annotations

import dataclasses

import numpy as np


# ---------------------------------------------------------------------------
# Image classification (paper's setting)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ImageTask:
    x_train: np.ndarray      # (N, D) float32 in [0, 1]
    y_train: np.ndarray      # (N,) int32
    x_test: np.ndarray
    y_test: np.ndarray
    num_classes: int
    dim: int


def _smooth_noise(rng, n, side, ch, scale):
    """Low-frequency noise: upsampled coarse grid (structured, image-like)."""
    coarse = rng.normal(size=(n, ch, side // 4, side // 4)) * scale
    up = coarse.repeat(4, axis=2).repeat(4, axis=3)
    return up.reshape(n, -1)


def _make_image_task(seed, n_train, n_test, side, ch, num_classes,
                     proto_scale, noise_scale, overlap, max_shift=3):
    rng = np.random.default_rng(seed)
    dim = side * side * ch
    # smooth prototypes (blob-like, so pixels are spatially correlated)
    protos = _smooth_noise(rng, num_classes, side, ch, proto_scale)
    if overlap:
        # mix prototypes so classes share structure (harder task)
        mix = rng.dirichlet(np.ones(num_classes) * 0.4, size=num_classes)
        protos = mix @ protos
    protos_img = protos.reshape(num_classes, ch, side, side)

    def sample(n, rng):
        y = rng.integers(0, num_classes, size=n).astype(np.int32)
        x = protos_img[y]
        if max_shift:
            # translation jitter (MNIST-style position variance) — breaks
            # linear separability while MLPs cope fine
            dx = rng.integers(-max_shift, max_shift + 1, size=n)
            dy = rng.integers(-max_shift, max_shift + 1, size=n)
            x = np.stack([np.roll(np.roll(im, a, axis=1), b, axis=2)
                          for im, a, b in zip(x, dx, dy)])
        x = x.reshape(n, dim)
        x = x + _smooth_noise(rng, n, side, ch, noise_scale)
        x = x + rng.normal(size=(n, dim)) * noise_scale * 0.5
        x = 1.0 / (1.0 + np.exp(-x))                     # into [0, 1]
        return x.astype(np.float32), y

    x_tr, y_tr = sample(n_train, rng)
    x_te, y_te = sample(n_test, rng)
    return ImageTask(x_tr, y_tr, x_te, y_te, num_classes, dim)


def mnist_like(seed=0, n_train=6000, n_test=1000):
    """28x28x1, 10 classes, separable but not linearly (MNIST stand-in)."""
    return _make_image_task(seed, n_train, n_test, side=28, ch=1,
                            num_classes=10, proto_scale=2.0,
                            noise_scale=0.8, overlap=False, max_shift=4)


def cifar_like(seed=0, n_train=6000, n_test=1000):
    """32x32x3, 10 classes, overlapping prototypes + heavy noise."""
    return _make_image_task(seed + 7, n_train, n_test, side=32, ch=3,
                            num_classes=10, proto_scale=1.0,
                            noise_scale=0.9, overlap=True, max_shift=3)


def shard_task(task: ImageTask, node: int, num_nodes: int) -> ImageTask:
    """Federated split: node-local training shard, shared test set."""
    idx = np.arange(node, len(task.x_train), num_nodes)
    return dataclasses.replace(task, x_train=task.x_train[idx],
                               y_train=task.y_train[idx])


def batches(x, y, batch_size, seed):
    """Shuffled minibatch index iterator (one epoch)."""
    rng = np.random.default_rng(seed)
    order = rng.permutation(len(x))
    for i in range(0, len(x) - batch_size + 1, batch_size):
        j = order[i:i + batch_size]
        yield x[j], y[j]


# ---------------------------------------------------------------------------
# Language modelling (synthetic Markov corpus)
# ---------------------------------------------------------------------------

class MarkovLM:
    """First-order Markov chain with sparse transitions + Zipf marginal."""

    def __init__(self, vocab, seed=0, branching=32):
        rng = np.random.default_rng(seed)
        self.vocab = vocab
        self.branching = branching
        # each token can transition to `branching` successors
        self.succ = rng.integers(0, vocab, size=(vocab, branching))
        w = rng.pareto(1.2, size=(vocab, branching)) + 0.05
        self.probs = (w / w.sum(1, keepdims=True)).astype(np.float64)

    def sample(self, batch, seq_len, seed):
        rng = np.random.default_rng(seed)
        out = np.empty((batch, seq_len), np.int32)
        tok = rng.integers(0, self.vocab, size=batch)
        for t in range(seq_len):
            out[:, t] = tok
            choice = np.array(
                [rng.choice(self.branching, p=self.probs[k]) for k in tok])
            tok = self.succ[tok, choice]
        return out


def lm_batches(vocab, batch, seq_len, steps, seed=0):
    """Yields (batch, seq_len + 1) int32 token blocks for `steps` steps."""
    chain = MarkovLM(min(vocab, 4096), seed)
    for s in range(steps):
        yield chain.sample(batch, seq_len + 1, seed * 100003 + s) % vocab
