"""Block-level init/apply/decode dispatch.

Block kinds: attn | local_attn | cross_attn | mamba2 | rglru | xdec.
Every block is pre-norm residual; attn/rglru/xdec blocks are followed by
an MLP sub-layer (dense or MoE); mamba2 blocks have none when d_ff == 0.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention, common, mlp, rglru, ssm
from repro.models.mlp import NO_DIST


# ---------------------------------------------------------------------------
# Attention sub-layer
# ---------------------------------------------------------------------------

def attn_init(key, cfg, cross=False):
    d, H, KV = cfg.d_model, cfg.n_heads, cfg.n_kv
    hd = cfg.resolved_head_dim
    dtype = common.dtype_of(cfg)
    ks = jax.random.split(key, 5)
    p = {
        "wq": common.dense_init(ks[0], (d, H, hd), dtype, fan_in=d),
        "wk": common.dense_init(ks[1], (d, KV, hd), dtype, fan_in=d),
        "wv": common.dense_init(ks[2], (d, KV, hd), dtype, fan_in=d),
        "wo": common.dense_init(ks[3], (H, hd, d), dtype, fan_in=H * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dtype)
        p["bk"] = jnp.zeros((KV, hd), dtype)
        p["bv"] = jnp.zeros((KV, hd), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), jnp.float32)
        p["k_norm"] = jnp.zeros((hd,), jnp.float32)
    if cross:
        p["gate"] = jnp.zeros((), jnp.float32)
    return p


def _qkv(p, cfg, x, kv_src):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("btd,dhk->bthk", kv_src, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", kv_src, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q = common.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = common.rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def attn_apply(p, cfg, x, *, kv_src=None, causal=True, window=None,
               use_rope=True, q_offset=0):
    cross = kv_src is not None
    q, k, v = _qkv(p, cfg, x, x if kv_src is None else kv_src)
    if use_rope and not cross:
        pos_q = q_offset + jnp.arange(x.shape[1], dtype=jnp.int32)
        q = common.apply_rope(q, pos_q[None], cfg.rope_theta)
        k = common.apply_rope(k, pos_q[None], cfg.rope_theta)
    out = attention.chunked_attention(
        q, k, v, causal=causal and not cross, window=window,
        q_offset=q_offset,
        causal_skip=attention.DEFAULT_CAUSAL_SKIP and causal and not cross)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    if cross:
        y = jnp.tanh(p["gate"]).astype(y.dtype) * y
    return y


def attn_decode(p, cfg, cache, x, pos, *, window=None, use_rope=True):
    """x: (B, d) one token; cache: {k, v, kpos}. Returns (y, cache)."""
    q, k, v = _qkv(p, cfg, x[:, None], x[:, None])
    if use_rope:
        posv = jnp.full((1, 1), pos, jnp.int32)
        q = common.apply_rope(q, posv, cfg.rope_theta)
        k = common.apply_rope(k, posv, cfg.rope_theta)
    S = cache["k"].shape[1]
    slot = pos % S if window is not None else pos
    kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
    kpos = jax.lax.dynamic_update_slice_in_dim(
        cache["kpos"], jnp.asarray([pos], jnp.int32), slot, axis=0)
    y = attention.decode_attention(q[:, 0], kc, vc, kpos, pos, window=window)
    y = jnp.einsum("bhk,hkd->bd", y, p["wo"])
    return y, {"k": kc, "v": vc, "kpos": kpos}


def cross_decode(p, cfg, cross_kv, x):
    """Cross-attention for one decode token against precomputed enc/vision KV."""
    k, v, kpos = cross_kv["k"], cross_kv["v"], cross_kv["kpos"]
    q = jnp.einsum("bd,dhk->bhk", x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    if cfg.qk_norm:
        q = common.rms_norm(q, p["q_norm"], cfg.norm_eps)
    y = attention.decode_attention(q, k, v, kpos, jnp.int32(2 ** 30))
    y = jnp.einsum("bhk,hkd->bd", y, p["wo"])
    if "gate" in p:
        y = jnp.tanh(p["gate"]).astype(y.dtype) * y
    return y


def precompute_cross_kv(p, cfg, aux):
    """aux: (B, T, d) encoder/vision embeddings -> cache-side KV."""
    k = jnp.einsum("btd,dhk->bthk", aux, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", aux, p["wv"])
    if cfg.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        k = common.rms_norm(k, p["k_norm"], cfg.norm_eps)
    return {"k": k, "v": v,
            "kpos": jnp.arange(aux.shape[1], dtype=jnp.int32)}


# ---------------------------------------------------------------------------
# MLP sub-layer dispatch
# ---------------------------------------------------------------------------

def mlp_init(key, cfg):
    if cfg.moe is not None:
        return moe_wrap_init(key, cfg)
    if cfg.d_ff == 0:
        return None
    return mlp.dense_mlp_init(key, cfg.d_model, cfg.d_ff,
                              common.dtype_of(cfg))


def moe_wrap_init(key, cfg):
    import dataclasses
    m = cfg.moe
    if m.num_shared:
        m = dataclasses.replace(m, shared_ff=m.shared_ff)  # copy
    return mlp.moe_init(key, cfg.moe, cfg.d_model, common.dtype_of(cfg))


def mlp_apply(p, cfg, x, dist=NO_DIST):
    """Returns (y, aux_loss)."""
    if p is None:
        return jnp.zeros_like(x), jnp.zeros((), jnp.float32)
    if cfg.moe is not None:
        return mlp.moe_apply(p, x, cfg.moe, cfg.act, dist)
    return mlp.dense_mlp_apply(p, x, cfg.act), jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Block init / apply / decode
# ---------------------------------------------------------------------------

def block_init(key, cfg, kind):
    dnorm = jnp.zeros((cfg.d_model,), jnp.float32)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    if kind in ("attn", "local_attn"):
        p = {"norm1": dnorm, "attn": attn_init(k1, cfg)}
        m = mlp_init(k2, cfg)
        if m is not None:
            p["norm2"] = dnorm
            p["mlp"] = m
        return p
    if kind == "cross_attn":
        p = {"norm1": dnorm, "attn": attn_init(k1, cfg, cross=True)}
        m = mlp_init(k2, cfg)
        if m is not None:
            p["norm2"] = dnorm
            p["mlp"] = m
        return p
    if kind == "xdec":
        return {"norm1": dnorm, "attn": attn_init(k1, cfg),
                "norm_x": dnorm, "xattn": attn_init(k2, cfg, cross=True),
                "norm2": dnorm, "mlp": mlp_init(k3, cfg)}
    if kind == "mamba2":
        p = {"norm1": dnorm, "ssm": ssm.init(k1, cfg)}
        if cfg.d_ff:
            p["norm2"] = dnorm
            p["mlp"] = mlp_init(k2, cfg)
        return p
    if kind == "rglru":
        p = {"norm1": dnorm, "rec": rglru.init(k1, cfg)}
        m = mlp_init(k2, cfg)
        if m is not None:
            p["norm2"] = dnorm
            p["mlp"] = m
        return p
    raise ValueError(kind)


def _window_for(cfg, kind):
    if kind == "local_attn":
        return cfg.rglru.window if cfg.rglru else (cfg.window or 2048)
    return cfg.window


def block_apply(p, cfg, kind, x, ctx):
    """x: (B, S, d). ctx: dict(causal, aux, dist, q_offset).
    Returns (x_out, aux_loss)."""
    dist = ctx.get("dist", NO_DIST)
    aux_loss = jnp.zeros((), jnp.float32)
    causal = ctx.get("causal", True)
    q_off = ctx.get("q_offset", 0)
    if kind in ("attn", "local_attn"):
        h = common.rms_norm(x, p["norm1"], cfg.norm_eps)
        x = x + attn_apply(p["attn"], cfg, h, causal=causal,
                           window=_window_for(cfg, kind), q_offset=q_off)
    elif kind == "cross_attn":
        h = common.rms_norm(x, p["norm1"], cfg.norm_eps)
        x = x + attn_apply(p["attn"], cfg, h, kv_src=ctx["aux"],
                           use_rope=False)
    elif kind == "xdec":
        h = common.rms_norm(x, p["norm1"], cfg.norm_eps)
        x = x + attn_apply(p["attn"], cfg, h, causal=True, q_offset=q_off)
        h = common.rms_norm(x, p["norm_x"], cfg.norm_eps)
        x = x + attn_apply(p["xattn"], cfg, h, kv_src=ctx["aux"],
                           use_rope=False)
    elif kind == "mamba2":
        h = common.rms_norm(x, p["norm1"], cfg.norm_eps)
        x = x + ssm.forward(p["ssm"], cfg, h)
    elif kind == "rglru":
        h = common.rms_norm(x, p["norm1"], cfg.norm_eps)
        y, _, _ = rglru.forward(p["rec"], cfg, h)
        x = x + y
    else:
        raise ValueError(kind)
    if "mlp" in p:
        h = common.rms_norm(x, p["norm2"], cfg.norm_eps)
        y, aux_loss = mlp_apply(p["mlp"], cfg, h, dist)
        x = x + y
    return x, aux_loss


# ---------------------------------------------------------------------------
# Prefill: apply block AND produce a decode-ready cache
# ---------------------------------------------------------------------------

def _attn_prefill(p, cfg, x, *, window, max_len, causal=True):
    """Like attn_apply but also returns the KV cache after S tokens."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, cfg, x, x)
    pos = jnp.arange(S, dtype=jnp.int32)
    q = common.apply_rope(q, pos[None], cfg.rope_theta)
    k = common.apply_rope(k, pos[None], cfg.rope_theta)
    out = attention.chunked_attention(
        q, k, v, causal=causal, window=window,
        causal_skip=attention.DEFAULT_CAUSAL_SKIP and causal)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    if window is not None and window < max_len:
        Wc = min(window, max_len)
        keep = min(S, Wc)
        # ring layout: position p lives in slot p % Wc
        slots = jnp.asarray([p % Wc for p in range(S - keep, S)], jnp.int32)
        B_, _, KV, hd = k.shape
        ck = jnp.zeros((B_, Wc, KV, hd), k.dtype).at[:, slots].set(
            k[:, S - keep:])
        cv = jnp.zeros((B_, Wc, KV, hd), v.dtype).at[:, slots].set(
            v[:, S - keep:])
        kpos = jnp.full((Wc,), -1, jnp.int32).at[slots].set(
            jnp.arange(S - keep, S, dtype=jnp.int32))
        cache = {"k": ck, "v": cv, "kpos": kpos}
    else:
        L = max_len
        pad = L - S
        cache = {
            "k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
            "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))),
            "kpos": jnp.concatenate(
                [pos, jnp.full((pad,), -1, jnp.int32)]),
        }
    return y, cache


def block_prefill(p, cfg, kind, x, ctx):
    """x: (B, S, d). Returns (x_out, cache) — cache matches block_decode."""
    max_len = ctx.get("max_len", x.shape[1])
    dist = ctx.get("dist", NO_DIST)
    if kind in ("attn", "local_attn"):
        window = _window_for(cfg, kind)
        h = common.rms_norm(x, p["norm1"], cfg.norm_eps)
        y, cache = _attn_prefill(p["attn"], cfg, h, window=window,
                                 max_len=max_len)
        x = x + y
    elif kind == "cross_attn":
        h = common.rms_norm(x, p["norm1"], cfg.norm_eps)
        x = x + attn_apply(p["attn"], cfg, h, kv_src=ctx["aux"],
                           use_rope=False)
        cache = precompute_cross_kv(p["attn"], cfg, ctx["aux"])
    elif kind == "xdec":
        h = common.rms_norm(x, p["norm1"], cfg.norm_eps)
        y, self_c = _attn_prefill(p["attn"], cfg, h, window=None,
                                  max_len=max_len)
        x = x + y
        h = common.rms_norm(x, p["norm_x"], cfg.norm_eps)
        x = x + attn_apply(p["xattn"], cfg, h, kv_src=ctx["aux"],
                           use_rope=False)
        cache = {"self": self_c,
                 "cross": precompute_cross_kv(p["xattn"], cfg, ctx["aux"])}
    elif kind == "mamba2":
        h = common.rms_norm(x, p["norm1"], cfg.norm_eps)
        y, cache = ssm.forward(p["ssm"], cfg, h, return_cache=True)
        x = x + y
    elif kind == "rglru":
        h = common.rms_norm(x, p["norm1"], cfg.norm_eps)
        y, hT, conv_tail = rglru.forward(p["rec"], cfg, h)
        cache = {"h": hT, "conv": conv_tail}
        x = x + y
    else:
        raise ValueError(kind)
    if "mlp" in p:
        h = common.rms_norm(x, p["norm2"], cfg.norm_eps)
        y, _ = mlp_apply(p["mlp"], cfg, h, dist)
        x = x + y
    return x, cache


# ---------------------------------------------------------------------------
# Decode caches
# ---------------------------------------------------------------------------

def block_cache_init(cfg, kind, batch, max_len, dtype):
    KV, hd = cfg.n_kv, cfg.resolved_head_dim
    window = _window_for(cfg, kind)

    def kv_cache(length):
        return {"k": jnp.zeros((batch, length, KV, hd), dtype),
                "v": jnp.zeros((batch, length, KV, hd), dtype),
                "kpos": jnp.full((length,), -1, jnp.int32)}

    if kind == "attn":
        return kv_cache(max_len if cfg.window is None
                        else min(cfg.window, max_len))
    if kind == "local_attn":
        return kv_cache(min(window, max_len))
    if kind == "cross_attn":
        # filled by precompute_cross_kv at prefill time
        t = cfg.vision_tokens or cfg.enc_seq
        return kv_cache(t)
    if kind == "xdec":
        return {"self": kv_cache(max_len),
                "cross": kv_cache(cfg.enc_seq)}
    if kind == "mamba2":
        return ssm.init_cache(cfg, batch, dtype)
    if kind == "rglru":
        return rglru.init_cache(cfg, batch, dtype)
    raise ValueError(kind)


def block_decode(p, cfg, kind, cache, x, pos, ctx):
    """x: (B, d) one token. Returns (x_out, new_cache)."""
    if kind in ("attn", "local_attn"):
        window = _window_for(cfg, kind)
        ring = window is not None
        h = common.rms_norm(x, p["norm1"], cfg.norm_eps)
        y, cache = attn_decode(p["attn"], cfg, cache, h, pos,
                               window=window if ring else None)
        x = x + y
    elif kind == "cross_attn":
        h = common.rms_norm(x, p["norm1"], cfg.norm_eps)
        x = x + cross_decode(p["attn"], cfg, cache, h)
    elif kind == "xdec":
        h = common.rms_norm(x, p["norm1"], cfg.norm_eps)
        y, self_c = attn_decode(p["attn"], cfg, cache["self"], h, pos)
        x = x + y
        h = common.rms_norm(x, p["norm_x"], cfg.norm_eps)
        x = x + cross_decode(p["xattn"], cfg, cache["cross"], h)
        cache = {"self": self_c, "cross": cache["cross"]}
    elif kind == "mamba2":
        h = common.rms_norm(x, p["norm1"], cfg.norm_eps)
        y, cache = ssm.decode_step(p["ssm"], cfg, cache, h)
        x = x + y
    elif kind == "rglru":
        h = common.rms_norm(x, p["norm1"], cfg.norm_eps)
        y, cache = rglru.decode_step(p["rec"], cfg, cache, h)
        x = x + y
    else:
        raise ValueError(kind)
    if "mlp" in p:
        h = common.rms_norm(x, p["norm2"], cfg.norm_eps)
        y, _ = mlp_apply(p["mlp"], cfg, h[:, None], ctx.get("dist", NO_DIST))
        x = x + y[:, 0]
    return x, cache
