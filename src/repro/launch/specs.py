"""ShapeDtypeStruct input stand-ins for every (arch x input-shape) pair.

Nothing here allocates device memory — dry-runs lower against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import sharding
from repro.configs.base import SUBQUADRATIC
from repro.models import common, transformer


def _sds(shape, dtype, mesh=None, spec=None):
    if mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    spec = sharding._fit(spec, shape, mesh)     # drop non-divisible axes
    return jax.ShapeDtypeStruct(
        shape, dtype, sharding=jax.sharding.NamedSharding(mesh, spec))


def aux_shape(cfg, batch):
    """Stub-frontend embedding shape for audio/vlm archs (else None)."""
    if cfg.enc_dec:
        return (batch, cfg.enc_seq, cfg.d_model)
    if cfg.vision_tokens:
        return (batch, cfg.vision_tokens, cfg.vision_dim or cfg.d_model)
    return None


def train_input_specs(cfg, shape, mesh=None, batch_axes=None):
    """batch dict for the FF/BP train step: tokens (B, S+1) + optional aux."""
    B, S = shape.global_batch, shape.seq_len

    def bspec(rank):
        if mesh is None:
            return None
        if batch_axes is None:
            return sharding.data_spec(mesh, rank)
        dims = [None] * rank
        dims[0] = batch_axes if len(batch_axes) > 1 else batch_axes[0]
        return jax.sharding.PartitionSpec(*dims)

    batch = {"tokens": _sds((B, S + 1), jnp.int32, mesh, bspec(2))}
    ash = aux_shape(cfg, B)
    if ash is not None:
        batch["aux"] = _sds(ash, common.dtype_of(cfg), mesh, bspec(3))
    return batch


def prefill_input_specs(cfg, shape, mesh=None):
    B, S = shape.global_batch, shape.seq_len
    bspec = sharding.data_spec(mesh, 2) if mesh else None
    out = {"tokens": _sds((B, S), jnp.int32, mesh, bspec)}
    ash = aux_shape(cfg, B)
    if ash is not None:
        aspec = sharding.data_spec(mesh, 3) if mesh else None
        out["aux"] = _sds(ash, common.dtype_of(cfg), mesh, aspec)
    return out


def decode_input_specs(cfg, shape, mesh=None):
    """(caches, tokens, pos) specs for serve_step with a seq_len-deep cache."""
    B, S = shape.global_batch, shape.seq_len
    caches = transformer.cache_specs(cfg, B, S)
    if mesh is not None:
        cspecs = sharding.cache_specs_tree(
            caches, mesh, seq_axis_model=(B == 1))
        caches = jax.tree.map(
            lambda s, sp: _sds(s.shape, s.dtype, mesh, sp), caches, cspecs,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    tspec = sharding.data_spec(mesh, 1) if mesh else None
    tokens = _sds((B,), jnp.int32, mesh, tspec)
    pos = _sds((), jnp.int32, mesh, jax.sharding.PartitionSpec()) \
        if mesh else _sds((), jnp.int32)
    return caches, tokens, pos


def param_specs_abstract(cfg, mesh=None, with_opt=True, seed=0):
    """Abstract (ShapeDtypeStruct) params + optimizer state, sharded."""
    p_shape = jax.eval_shape(
        lambda k: transformer.init(k, cfg), jax.random.PRNGKey(seed))
    if with_opt:
        o_shape = jax.eval_shape(lambda: {
            "m": jax.tree.map(
                lambda s: jnp.zeros(s.shape, jnp.float32), p_shape),
            "v": jax.tree.map(
                lambda s: jnp.zeros(s.shape, jnp.float32), p_shape)})
    else:
        o_shape = None
    if mesh is None:
        return p_shape, o_shape
    specs = sharding.param_specs(p_shape, mesh)
    ns = jax.sharding.NamedSharding

    def attach(s, sp):
        return jax.ShapeDtypeStruct(s.shape, s.dtype,
                                    sharding=ns(mesh, sp))

    p_sds = jax.tree.map(
        attach, p_shape, specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    if with_opt:
        o_specs = {"m": specs, "v": specs}
        o_sds = jax.tree.map(
            attach, o_shape, o_specs,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    else:
        o_sds = None
    return p_sds, o_sds


def combo_is_applicable(cfg, shape_name):
    """long_500k only for sub-quadratic sequence mixing."""
    if shape_name == "long_500k" and cfg.name not in SUBQUADRATIC:
        return False
    return True
