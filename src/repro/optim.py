"""Adam optimizer (pytree-native) + the paper's LR cooldown schedule.

Written as plain functions over pytrees so the FF train step can apply
per-layer updates *inside* a ``lax.scan`` over stacked layer params — the
optimizer state is a pytree of the same structure/stacking as the params.

State: {"m": tree, "v": tree} in float32 (params may be bf16). The step
count is passed explicitly (it is the training loop's step counter) so
state stays a pure array pytree that shards exactly like the params.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adam_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params)}


def adam_update(params, grads, state, *, lr, step, b1=0.9, b2=0.999,
                eps=1e-8, weight_decay=0.0):
    """Returns (new_params, new_state). ``step`` is 1-based (scalar)."""
    t = jnp.asarray(step, jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def moments(g, m, v):
        gf = g.astype(jnp.float32)
        return (b1 * m + (1 - b1) * gf,
                b2 * v + (1 - b2) * jnp.square(gf))

    def upd(p, m2, v2):
        u = (m2 / c1) / (jnp.sqrt(v2 / c2) + eps)
        if weight_decay:
            u = u + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    # three maps (XLA CSEs the shared subexpressions under jit)
    new_m = jax.tree.map(lambda g, m, v: moments(g, m, v)[0],
                         grads, state["m"], state["v"])
    new_v = jax.tree.map(lambda g, m, v: moments(g, m, v)[1],
                         grads, state["m"], state["v"])
    new_p = jax.tree.map(upd, params, new_m, new_v)
    return new_p, {"m": new_m, "v": new_v}


def cooldown_lr(base_lr, epoch, total_epochs, cooldown_after=0.5):
    """Paper §5.1: constant LR, then linear decay to 0 after the midpoint.

    Works with scalar or traced ``epoch`` (can be fractional).
    """
    frac = jnp.asarray(epoch, jnp.float32) / max(total_epochs, 1)
    scale = jnp.clip((1.0 - frac) / max(1.0 - cooldown_after, 1e-9), 0.0, 1.0)
    return base_lr * scale
