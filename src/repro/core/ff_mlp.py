"""The paper's network: [784, 2000, 2000, 2000, 2000] ReLU MLP trained
with Forward-Forward, layer by layer, in chapters (splits).

Faithful details:
  * label overlay on the first C pixels (pos = true, neg = wrong label)
  * goodness = sum of squared activities, loss = softplus(±(theta - g))
  * activity vectors are length-normalized between layers (Hinton), so a
    layer cannot cheat by reading its input's magnitude
  * Adam per layer; LR cooldown after half the epochs (paper §5.1)
  * Goodness prediction accumulates layers 2..L (all but first)
  * Softmax head consumes normalized activations of layers 2..L and is
    trained with layer-local backprop (it never propagates into FF layers)
  * Performance-Optimized goodness: per-layer softmax classifier trained
    with two-layer-deep backprop, no negative data (paper §4.4)

Every chapter-level unit of work is timed; ``repro.core.pff`` replays the
timings under the PFF schedules to derive distributed training time.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import optim
from repro.core import ff, strategies
from repro.kernels import ff_dense as kernels_ff_dense, ops


def _norm(x, eps=kernels_ff_dense.NORM_EPS):
    """Hinton's length normalization — applied to RAW inputs (label
    overlays) before the first layer. Between layers the divide is fused
    into the ``ff_dense`` kernel epilogue (``norm=True``); this XLA form
    remains only where no ff_dense call produces the activation."""
    return x / (jnp.linalg.norm(x, axis=-1, keepdims=True) + eps)


def fwd_norm(lp, x, impl="auto"):
    """One layer forward + Hinton length-norm — the inter-layer hand-off
    shared by the sequential trainer and the real executor (weight-stream
    bit-exactness depends on BOTH calling exactly this). One fused
    ``ff_dense`` dispatch with the norm divide in the kernel epilogue:
    activation, normalizer AND the divide in a single pass."""
    yn, _ = ops.ff_dense(x, lp["w"], lp["b"], impl=impl, norm=True)
    return yn

def kernel_impl(cfg):
    """The config's ``ops.ff_dense`` path (auto | pallas | ref)."""
    return getattr(cfg, "kernel_impl", "auto")


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init(key, cfg):
    sizes = cfg.layer_sizes
    n_hidden = len(sizes) - 1
    ks = jax.random.split(key, n_hidden + 1)
    layers = []
    for i in range(n_hidden):
        w = jax.random.normal(ks[i], (sizes[i], sizes[i + 1]),
                              jnp.float32) * sizes[i] ** -0.5
        layers.append({"w": w, "b": jnp.zeros((sizes[i + 1],))})
    # layers 2..L feed the head (all of them for a 1-hidden-layer net)
    feat_dim = sum(sizes[2:]) or sizes[-1]
    head = {"w": jax.random.normal(ks[-1], (feat_dim, cfg.num_classes),
                                   jnp.float32) * feat_dim ** -0.5,
            "b": jnp.zeros((cfg.num_classes,))}
    params = {"layers": layers, "head": head}
    extras_init = strategies.goodness.get(cfg.goodness_fn).init_extras
    if extras_init is not None:
        params.update(extras_init(ks[-1], cfg))
    return params


def opt_init(params):
    out = {"layers": [optim.adam_init(lp) for lp in params["layers"]],
           "head": optim.adam_init(params["head"])}
    if "local_heads" in params:
        out["local_heads"] = [optim.adam_init(h)
                              for h in params["local_heads"]]
    return out


def layer_apply(lp, x):
    return jax.nn.relu(x @ lp["w"] + lp["b"])


# ---------------------------------------------------------------------------
# Layer-local training (one chapter = C mini-epochs over all batches)
# ---------------------------------------------------------------------------

def _num_batches(n, batch):
    """Batches per mini-epoch, tail included (ceil division)."""
    return -(-n // batch)


def _epoch_perm(key, ei, n, batch):
    """Shuffled sample order for mini-epoch ``ei``, length padded to a
    whole number of batches by WRAPPING the permutation.

    The old ``n // batch`` truncation silently dropped up to ``batch-1``
    samples every mini-epoch — which especially bites Federated PFF,
    whose per-node shards (e.g. 15000/4 nodes) are rarely divisible by
    the batch size. Wrapping guarantees every sample is consumed at
    least once per mini-epoch (the leading samples of the shuffled
    order repeat, an unbiased choice because the permutation is fresh
    per epoch) while keeping every batch full — shapes stay static for
    ``lax.scan``. Tiling (not a single wrap) also covers shards SMALLER
    than one batch (n < batch), where the old code trained on nothing.
    """
    perm = jax.random.permutation(jax.random.fold_in(key, ei), n)
    total = _num_batches(n, batch) * batch
    if total > n:
        perm = jnp.tile(perm, -(-total // n))[:total]
    return perm


def _ff_layer_loss(lp, xb, theta, peer_w, impl="auto"):
    """FF objective over a stacked [pos; neg] batch xb: (2B, K).

    Goodness = MEAN of squared activities with theta ~ 2 (equivalent to
    the paper's sum-of-squares with theta = 2*width; the mean form keeps
    one theta valid across layer widths). Stacking pos and neg into ONE
    (2B, K) matmul halves the kernel dispatches of the old two-pass form
    and doubles MXU occupancy; the goodness vector is split afterwards.
    ``impl`` selects the fused Pallas kernel vs the jnp oracle
    (repro.kernels.ops.ff_dense).
    """
    y, g = ops.ff_dense(xb, lp["w"], lp["b"], impl=impl)
    g = g / y.shape[-1]                       # sum-of-squares -> mean
    half = xb.shape[0] // 2
    loss = ff.ff_loss(g[:half], g[half:], theta)
    if peer_w:
        loss = loss + peer_w * ff.peer_norm_loss(y[:half])
    return loss


@functools.partial(jax.jit, static_argnames=("batch", "epochs", "theta",
                                             "peer_w", "impl"),
                   donate_argnums=(0, 1))
def train_layer_chapter(lp, opt, x_pos, x_neg, lrs, key, *, batch, epochs,
                        theta, peer_w=0.0, impl="auto"):
    """Trains one layer for `epochs` mini-epochs. x_pos/x_neg are this
    layer's (already normalized) inputs over the whole train set.
    lrs: (epochs,) learning rate per mini-epoch (cooldown-aware).
    lp/opt are donated: their buffers are reused for the outputs."""
    n = x_pos.shape[0]
    n_batches = _num_batches(n, batch)

    def epoch_body(carry, ei):
        lp, opt, step = carry
        perm = _epoch_perm(key, ei, n, batch)

        def batch_body(carry, bi):
            lp, opt, step = carry
            idx = jax.lax.dynamic_slice_in_dim(perm, bi * batch, batch)
            xb = jnp.concatenate([x_pos[idx], x_neg[idx]], axis=0)
            g = jax.grad(_ff_layer_loss)(lp, xb, theta, peer_w, impl)
            step = step + 1
            lp, opt = optim.adam_update(lp, g, opt, lr=lrs[ei], step=step)
            return (lp, opt, step), None

        (lp, opt, step), _ = jax.lax.scan(
            batch_body, (lp, opt, step), jnp.arange(n_batches))
        return (lp, opt, step), None

    (lp, opt, step), _ = jax.lax.scan(
        epoch_body, (lp, opt, jnp.zeros((), jnp.int32)),
        jnp.arange(epochs))
    return lp, opt


def _perf_opt_loss(lp_and_head, xb, yb, impl="auto"):
    """§4.4 local-head loss on the fused kernel path: activation,
    normalizer AND the norm divide come from one ``ff_dense`` dispatch
    (norm=True — in-kernel epilogue on Pallas, with a matching
    custom_vjp); only the small (N, C) head matmul stays a plain dot."""
    lp, head = lp_and_head
    yn, _ = ops.ff_dense(xb, lp["w"], lp["b"], impl=impl, norm=True)
    logits = yn @ head["w"] + head["b"]
    return jnp.mean(
        -jax.nn.log_softmax(logits)[jnp.arange(xb.shape[0]), yb])


@functools.partial(jax.jit, static_argnames=("batch", "epochs", "impl"),
                   donate_argnums=(0, 1, 2, 3))
def train_layer_chapter_perf_opt(lp, head, opt, opt_h, x, y, lrs, key, *,
                                 batch, epochs, impl="auto"):
    """Performance-Optimized goodness (paper §4.4): train (layer, local
    softmax head) with two-layer backprop; no negative data.
    lp/head/opt/opt_h are donated."""
    n = x.shape[0]
    n_batches = _num_batches(n, batch)

    def epoch_body(carry, ei):
        lp, head, opt, opt_h, step = carry
        perm = _epoch_perm(key, ei, n, batch)

        def batch_body(carry, bi):
            lp, head, opt, opt_h, step = carry
            idx = jax.lax.dynamic_slice_in_dim(perm, bi * batch, batch)
            g_lp, g_h = jax.grad(_perf_opt_loss)((lp, head), x[idx], y[idx],
                                                 impl)
            step = step + 1
            lp, opt = optim.adam_update(lp, g_lp, opt, lr=lrs[ei], step=step)
            head, opt_h = optim.adam_update(head, g_h, opt_h, lr=lrs[ei],
                                            step=step)
            return (lp, head, opt, opt_h, step), None

        (lp, head, opt, opt_h, step), _ = jax.lax.scan(
            batch_body, (lp, head, opt, opt_h, step),
            jnp.arange(n_batches))
        return (lp, head, opt, opt_h, step), None

    (lp, head, opt, opt_h, _), _ = jax.lax.scan(
        epoch_body, (lp, head, opt, opt_h, jnp.zeros((), jnp.int32)),
        jnp.arange(epochs))
    return lp, head, opt, opt_h


def _head_loss(head, feats, y):
    logits = feats @ head["w"] + head["b"]
    return jnp.mean(-jax.nn.log_softmax(logits)[jnp.arange(y.shape[0]), y])


@functools.partial(jax.jit, static_argnames=("batch", "epochs"),
                   donate_argnums=(0, 1))
def train_head_chapter(head, opt, feats, y, lrs, key, *, batch, epochs):
    """Softmax head on concatenated normalized feats of layers 2..L.
    head/opt are donated."""
    n = feats.shape[0]
    n_batches = _num_batches(n, batch)

    def epoch_body(carry, ei):
        head, opt, step = carry
        perm = _epoch_perm(key, ei, n, batch)

        def batch_body(carry, bi):
            head, opt, step = carry
            idx = jax.lax.dynamic_slice_in_dim(perm, bi * batch, batch)
            g = jax.grad(_head_loss)(head, feats[idx], y[idx])
            step = step + 1
            head, opt = optim.adam_update(head, g, opt, lr=lrs[ei],
                                          step=step)
            return (head, opt, step), None

        (head, opt, step), _ = jax.lax.scan(
            batch_body, (head, opt, step), jnp.arange(n_batches))
        return (head, opt, step), None

    (head, opt, _), _ = jax.lax.scan(
        epoch_body, (head, opt, jnp.zeros((), jnp.int32)),
        jnp.arange(epochs))
    return head, opt


# ---------------------------------------------------------------------------
# Prediction / evaluation
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("impl",))
def accumulated_goodness(layers_params, x, impl="auto"):
    """Goodness of layers 2..L (all but first), summed. x already
    label-overlaid. Returns (B,). Runs on the fused kernel path: each
    layer is ONE ff_dense dispatch computing activation, goodness AND
    the next layer's normalized input (norm=True epilogue) — the
    separate per-layer norm reduce + divide are gone."""
    hn = _norm(x)
    total = jnp.zeros((x.shape[0],), jnp.float32)
    skip_first = len(layers_params) > 1
    for i, lp in enumerate(layers_params):
        # the last layer's normalized output feeds nothing — skip the
        # epilogue there (on Pallas that is a whole normalize sweep)
        feeds_next = i + 1 < len(layers_params)
        yn, g = ops.ff_dense(hn, lp["w"], lp["b"], impl=impl,
                             norm=feeds_next)
        if i >= 1 or not skip_first:
            total = total + g / yn.shape[-1]
        hn = yn
    return total


@functools.partial(jax.jit, static_argnames=("num_classes", "impl"))
def goodness_class_scores(params, x, num_classes, impl="auto"):
    """(B, C) accumulated-goodness score per candidate label.

    All C label overlays are stacked into one (C*B, D) batch, so the
    whole prediction sweep is ONE fused dispatch per layer instead of a
    vmap of C separate layer stacks."""
    B, D = x.shape
    xs = jnp.broadcast_to(x[None], (num_classes, B, D)).reshape(
        num_classes * B, D)
    labels = jnp.repeat(jnp.arange(num_classes), B)
    xc = ff.overlay_label(xs, labels, num_classes)
    scores = accumulated_goodness(params["layers"], xc, impl=impl)
    return scores.reshape(num_classes, B).T


@functools.partial(jax.jit, static_argnames=("impl",))
def softmax_feats(layers_params, x, impl="auto"):
    """Normalized activations of layers 2..L, concatenated (all layers
    for a 1-hidden-layer net). Each layer is one fused ``ff_dense``
    dispatch: the goodness output doubles as the feature normalizer."""
    feats = []
    hn = _norm(x)
    for lp in layers_params:
        hn, _ = ops.ff_dense(hn, lp["w"], lp["b"], impl=impl, norm=True)
        feats.append(hn)
    if len(feats) > 1:
        feats = feats[1:]
    return jnp.concatenate(feats, axis=-1)


@functools.partial(jax.jit, static_argnames=("last_only", "impl"))
def perf_opt_scores(params, x, last_only=False, impl="auto"):
    """Performance-Optimized prediction (paper Table 4): sum the local
    classifier logits over all layers, or use only the last layer's.
    The per-layer dense+norm runs on the fused kernel path."""
    hn = _norm(x)
    total = None
    for lp, head in zip(params["layers"], params["local_heads"]):
        hn, _ = ops.ff_dense(hn, lp["w"], lp["b"], impl=impl, norm=True)
        logits = jax.nn.log_softmax(hn @ head["w"] + head["b"])
        total = logits if (total is None or last_only) else total + logits
    return total


def class_scores(params, x, num_classes, mode="goodness", impl="auto"):
    """(B, C) label scores via the classifier strategy registry."""
    strat = strategies.classifier.get(mode)
    return strat.scores(params, x, num_classes=num_classes, impl=impl)


def predict(params, x, num_classes, mode="goodness", impl="auto"):
    return jnp.argmax(class_scores(params, x, num_classes, mode,
                                   impl=impl), axis=1)


def chunked_scores(score_fn, x, chunk=2000):
    """Applies ``score_fn`` over ``x`` in test-time chunks (bounding the
    prediction sweep's memory: each chunk expands C-fold inside the
    goodness scorer) and concatenates along axis 0. The ONE chunked
    evaluation loop — the trainers' adaptive-negatives scoring and
    ``accuracy`` both run through here."""
    outs = [score_fn(jnp.asarray(x[i:i + chunk]))
            for i in range(0, len(x), chunk)]
    return jnp.concatenate(outs, axis=0)


def accuracy(params, x, y, num_classes, mode="goodness", chunk=2000,
             impl="auto"):
    scores = chunked_scores(
        lambda xc: class_scores(params, xc, num_classes, mode, impl=impl),
        x, chunk=chunk)
    pred = jnp.argmax(scores, axis=1)
    return float(jnp.mean(pred == jnp.asarray(y)))


# ---------------------------------------------------------------------------
# Builtin strategies (see repro.core.strategies; surfaced via repro.api)
# ---------------------------------------------------------------------------

def _sumsq_get_state(params, opt, k):
    return (params["layers"][k], opt["layers"][k])


def _sumsq_set_state(params, opt, k, state):
    params["layers"][k], opt["layers"][k] = state


def _sumsq_train_chapter(state, acts, extras, lrs, key, *, cfg, epochs):
    lp, o = state
    xp, xn = acts
    return train_layer_chapter(
        lp, o, xp, xn, lrs, key, batch=cfg.batch_size, epochs=epochs,
        theta=cfg.theta, peer_w=cfg.peer_w, impl=kernel_impl(cfg))


def _perf_opt_init_extras(key, cfg):
    sizes = cfg.layer_sizes
    n_hidden = len(sizes) - 1
    kk = jax.random.split(key, n_hidden)
    return {"local_heads": [
        {"w": jax.random.normal(kk[i], (sizes[i + 1], cfg.num_classes),
                                jnp.float32) * sizes[i + 1] ** -0.5,
         "b": jnp.zeros((cfg.num_classes,))}
        for i in range(n_hidden)]}


def _perf_opt_get_state(params, opt, k):
    return (params["layers"][k], params["local_heads"][k],
            opt["layers"][k], opt["local_heads"][k])


def _perf_opt_set_state(params, opt, k, state):
    (params["layers"][k], params["local_heads"][k],
     opt["layers"][k], opt["local_heads"][k]) = state


def _perf_opt_train_chapter(state, acts, extras, lrs, key, *, cfg, epochs):
    lp, head, o, oh = state
    (xk,) = acts
    (y,) = extras
    return train_layer_chapter_perf_opt(
        lp, head, o, oh, xk, y, lrs, key, batch=cfg.batch_size,
        epochs=epochs, impl=kernel_impl(cfg))


strategies.register_goodness("sumsq", strategies.GoodnessStrategy(
    name="sumsq", uses_negatives=True,
    get_state=_sumsq_get_state, set_state=_sumsq_set_state,
    train_chapter=_sumsq_train_chapter,
    export=lambda states: {"layers": [s[0] for s in states]},
    eval_mode=lambda cfg: cfg.classifier))

strategies.register_goodness("perf_opt", strategies.GoodnessStrategy(
    name="perf_opt", uses_negatives=False,
    get_state=_perf_opt_get_state, set_state=_perf_opt_set_state,
    train_chapter=_perf_opt_train_chapter,
    export=lambda states: {"layers": [s[0] for s in states],
                           "local_heads": [s[1] for s in states]},
    # honor an explicitly chosen classifier; only remap the config
    # DEFAULT ("goodness"), which scores label overlays the §4.4 layers
    # never saw — the strategy's own heads are the meaningful default
    eval_mode=lambda cfg: ("perf_opt_all" if cfg.classifier == "goodness"
                           else cfg.classifier),
    init_extras=_perf_opt_init_extras))


def _goodness_cls_scores(params, x, *, num_classes, impl="auto"):
    return goodness_class_scores(params, x, num_classes, impl=impl)


def _softmax_cls_scores(params, x, *, num_classes, impl="auto"):
    xn = ff.overlay_neutral(x, num_classes)
    feats = softmax_feats(params["layers"], xn, impl=impl)
    return feats @ params["head"]["w"] + params["head"]["b"]


def _perf_opt_cls_scores(last_only):
    def scores(params, x, *, num_classes, impl="auto"):
        xn = ff.overlay_neutral(x, num_classes)
        return perf_opt_scores(params, xn, last_only=last_only, impl=impl)
    return scores


strategies.register_classifier("goodness", _goodness_cls_scores)
strategies.register_classifier("softmax", _softmax_cls_scores,
                               trains_head=True)
strategies.register_classifier("perf_opt_all", _perf_opt_cls_scores(False),
                               requires_goodness="perf_opt")
strategies.register_classifier("perf_opt_last", _perf_opt_cls_scores(True),
                               requires_goodness="perf_opt")
