"""Negative-data strategy ablation on the LM (paper Table 1's dimension
applied to a transformer): random / fixed / adaptive token corruption.

Mirrors the paper's finding structure: adaptive (self-generated)
negatives cost an extra no-grad forward per step but give the hardest
training signal; fixed corruption patterns are cheapest but weakest.
Reported: eval CE + wall clock per mode at an equal step budget.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp

from repro import data as data_lib, optim
from repro.configs import get_config
from repro.core import train as train_lib
from repro.models import transformer


def run(arch="qwen2-0.5b", steps=40, batch=8, seq=96, lr=1e-3,
        out_dir="experiments"):
    base = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    eval_tokens = jnp.asarray(next(iter(
        data_lib.lm_batches(base.vocab, 16, seq, 1, seed=555))))
    out = {}
    for mode in ("random", "fixed", "adaptive"):
        cfg = dataclasses.replace(
            base, ff=dataclasses.replace(base.ff, neg_mode=mode))
        params = transformer.init(key, cfg)
        opt = optim.adam_init(params)
        step_fn = jax.jit(train_lib.make_ff_train_step(cfg, lr=lr))
        t0 = time.time()
        for i, tokens in enumerate(data_lib.lm_batches(
                cfg.vocab, batch, seq, steps, seed=0)):
            params, opt, m = step_fn(
                params, opt, {"tokens": jnp.asarray(tokens)}, i + 1)
        jax.block_until_ready(m["loss_ff"])
        ce = float(train_lib.eval_ce(params, cfg, eval_tokens))
        out[mode] = {"eval_ce": round(ce, 3),
                     "loss_ff_final": round(float(m["loss_ff"]), 4),
                     "wall_s": round(time.time() - t0, 1)}
        print(f"  {mode:8s}: eval_ce={ce:.3f} "
              f"loss_ff={out[mode]['loss_ff_final']} "
              f"wall={out[mode]['wall_s']}s")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "lm_negatives.json"), "w") as f:
        json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    run()
