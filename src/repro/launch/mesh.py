"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (smoke tests must keep seeing 1 CPU device; only dryrun.py sets
XLA_FLAGS for 512 placeholder devices).
"""
from __future__ import annotations

import jax

PFF_XLA_FLAG = "--xla_force_host_platform_device_count={n}"


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_pff_stage_mesh(*, stages: int = 2):
    """Beyond-paper PFF mode: the pod axis is the pipeline-STAGE axis —
    each pod owns a contiguous layer range, activations flow forward via
    collective_permute, and (FF having no backward pass) nothing flows
    back. See repro.core.pff_pod."""
    return jax.make_mesh((stages, 16, 16), ("stage", "data", "model"))


def make_host_mesh(axes=("data", "model")):
    """Whatever devices exist on this host, as a (1, n) or (n,) mesh —
    used by examples/tests on CPU."""
    n = len(jax.devices())
    if len(axes) == 2:
        return jax.make_mesh((1, n), axes)
    return jax.make_mesh((n,), axes)


def pff_node_devices(num_nodes: int):
    """One device per paper "node" for the real PFF executor
    (repro.core.pff_exec) — the first ``num_nodes`` entries of
    ``jax.devices()``.

    On CI/CPU, fake the paper's four compute nodes by exporting
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (see
    ``PFF_XLA_FLAG``) BEFORE jax is imported; on real hardware the
    accelerators are used as-is. Raises with that remedy when the host
    exposes too few devices.
    """
    devs = jax.devices()
    if len(devs) < num_nodes:
        raise RuntimeError(
            f"PFF executor needs {num_nodes} devices but jax sees only "
            f"{len(devs)}; export XLA_FLAGS="
            f"{PFF_XLA_FLAG.format(n=num_nodes)} before importing jax "
            f"(CI/CPU), or run on a host with >= {num_nodes} accelerators.")
    return list(devs[:num_nodes])
