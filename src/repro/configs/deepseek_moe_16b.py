"""deepseek-moe-16b — fine-grained MoE: 2 shared + 64 routed, top-6
[arXiv:2401.06066]."""
from repro.configs.base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-moe-16b",
    arch_type="moe",
    num_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv=16,
    d_ff=1408,
    vocab=102400,
    groups=((("attn",), 28),),
    moe=MoEConfig(num_experts=64, top_k=6, expert_ff=1408,
                  num_shared=2, shared_ff=2816, capacity_factor=1.25),
    source="arXiv:2401.06066 (DeepSeekMoE)",
))
