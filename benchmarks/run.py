"""Benchmark harness entry point: ``PYTHONPATH=src python -m benchmarks.run``.

Sections (one per paper table/figure + framework-level):
  1. paper tables 1-5 analogues (FF/PFF accuracy + schedule times)
  2. FF vs backprop on the synthetic LM (framework substrate)
  3. kernel validation sweep (Pallas vs oracle, interpret mode)
  3b. kernel autotuner sweep + table smoke (writes BENCH_kernel_tune.json)
  4. roofline table from the dry-run records (if present)
  5. FF hot-loop perf baseline (writes BENCH_ff_hotloop.json)

``--full`` runs the bigger paper-table configuration; default is the
quick profile (~10 min on this CPU container). ``--only=<section>``
selects one section — ``--only=ff_hotloop`` is the ``make bench-smoke``
target. Exits non-zero if any kernel-vs-oracle max error exceeds
``ERR_BUDGET``, if the fused kernel path leaks a separate norm-divide
op into the hand-off jaxpr, or if any argument is unrecognized (a
typo'd ``--only foo`` used to be ignored and silently run EVERY
section) — regressions and operator error both fail loudly in CI.
"""
from __future__ import annotations

import sys
import time

ERR_BUDGET = 1e-4


SECTIONS = ("tables", "lm", "lm_schedules", "lm_negatives", "lm_exec",
            "kernels", "tune", "roofline", "ff_hotloop", "pff_exec",
            "pff_faults", "serve", "trace")


def main(argv):
    full = False
    only = None
    for a in argv:
        if a == "--full":
            full = True
        elif a.startswith("--only="):
            only = a.split("=", 1)[1]
        else:
            # unknown flags (incl. the space form `--only foo`) used to
            # be dropped on the floor and every section would run
            print(f"unknown argument {a!r}; usage: python -m "
                  f"benchmarks.run [--full] "
                  f"[--only=<{'|'.join(SECTIONS)}>]")
            sys.exit(2)
    if only is not None and only not in SECTIONS:
        print(f"unknown --only section {only!r}; "
              f"expected one of {', '.join(SECTIONS)}")
        sys.exit(2)
    t0 = time.time()
    failures = []

    if only in (None, "tables"):
        print("\n##### 1. Paper tables 1-5 analogues #####")
        from benchmarks import paper_tables
        paper_tables.run_tables(quick=not full)

    if only in (None, "lm"):
        print("\n##### 2. FF vs backprop on the synthetic LM #####")
        from benchmarks import lm_ff
        lm_ff.run()

    if only in (None, "lm_schedules"):
        print("\n##### 2b. Joint-FF vs chapter-scheduled FF (paper's "
              "schedule on a transformer) #####")
        from benchmarks import lm_schedules
        lm_schedules.run()

    if only in (None, "lm_negatives"):
        print("\n##### 2c. LM negative-strategy ablation "
              "(random/fixed/adaptive corruption) #####")
        from benchmarks import lm_negatives
        lm_negatives.run()

    if only in (None, "lm_exec"):
        print("\n##### 2d. LM chapters on the real executor: bit-equality"
              " + CE budget on the BPE text source (multi-device) #####")
        from benchmarks import lm_exec
        res = lm_exec.run(quick=not full)
        failures.extend(res["failures"])

    if only in (None, "kernels"):
        print("\n##### 3. Kernel validation (Pallas interpret vs oracle) "
              "#####")
        from benchmarks import kernels as kbench
        worst = kbench.run()
        if worst > ERR_BUDGET:
            failures.append(f"kernel sweep max_err {worst:.2e} > "
                            f"{ERR_BUDGET:.0e}")

    if only in (None, "tune"):
        print("\n##### 3b. Kernel autotuner (measure-many, pick-fastest "
              "+ table smoke) #####")
        from benchmarks import kernels as kbench
        res = kbench.run_tune(quick=not full)
        failures.extend(res["failures"])

    if only in (None, "roofline"):
        print("\n##### 4. Roofline (from dry-run records) #####")
        from benchmarks import roofline
        roofline.main()

    if only in (None, "ff_hotloop"):
        print("\n##### 5. FF hot-loop baseline (ref vs fused) #####")
        from benchmarks import ff_hotloop
        res = ff_hotloop.run(quick=not full)
        if res["max_grad_err"] > ERR_BUDGET:
            failures.append(f"ff_hotloop grad max_err "
                            f"{res['max_grad_err']:.2e} > {ERR_BUDGET:.0e}")
        leaked = res["handoff_norm_divide_ops"]["pallas_fused"]
        if leaked:
            failures.append(
                f"ff_hotloop: {leaked} norm-divide op(s) outside the "
                f"fused kernel in the inter-layer hand-off jaxpr "
                f"(the divide must run in the kernel epilogue)")

    if only in (None, "pff_exec"):
        print("\n##### 6. Real PFF executor: measured vs simulated "
              "(multi-device) #####")
        from benchmarks import pff_exec as pexec_bench
        res = pexec_bench.run(quick=not full)
        failures.extend(res["failures"])

    if only in (None, "pff_faults"):
        print("\n##### 7. Executor resilience: checkpoint overhead + "
              "fault recovery (multi-device) #####")
        from benchmarks import pff_faults
        res = pff_faults.run(quick=not full)
        failures.extend(res["failures"])

    if only in (None, "serve"):
        print("\n##### 8. Serving: continuous batching + live hot-swap "
              "(multi-device) #####")
        from benchmarks import serve as serve_bench
        res = serve_bench.run(quick=not full)
        failures.extend(res["failures"])

    if only in (None, "trace"):
        print("\n##### 9. Observability: traced executor + serve run, "
              "critical-path gates (multi-device) #####")
        from benchmarks import trace as trace_bench
        res = trace_bench.run(quick=not full)
        failures.extend(res["failures"])

    print(f"\nbenchmarks done in {time.time() - t0:.0f}s")
    if failures:
        for f in failures:
            print(f"FAIL: {f}")
        sys.exit(1)


if __name__ == "__main__":
    main(sys.argv[1:])
