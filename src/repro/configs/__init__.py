from repro.configs.base import (  # noqa: F401
    INPUT_SHAPES, SUBQUADRATIC, FFConfig, InputShape, ModelConfig, MoEConfig,
    RGLRUConfig, SSMConfig, get_config, list_configs, register)
