"""Per-leg bench digest: every ``BENCH_*.json`` as a markdown table.

CI appends this module's stdout to ``$GITHUB_STEP_SUMMARY`` after the
smoke legs so each run's numbers (speedups, makespans, CE, kernel
errors, failure counts) are readable from the Actions summary page
without downloading the artifact bundle. Usage:

    python -m benchmarks.digest [dir]       # default: repo root

Pure stdlib on purpose — it must stay runnable even when a smoke leg
has poisoned the jax process state, and it never imports the benchmark
modules it summarizes. Raw Chrome traces (``traceEvents`` files) are
skipped; they are viewer input, not a summary.
"""
from __future__ import annotations

import glob
import json
import os
import sys

_MAX_COLS = 8          # keep tables readable on the Actions summary page
_MAX_ROWS = 12
_MAX_STR = 40
# column-name fragments worth a slot, in priority order
_PREFERRED = ("schedule", "nodes", "name", "kind", "impl", "shape",
              "speedup", "makespan", "latency", "err", "ce", "acc",
              "bit_exact", "hits", "util")


def _flatten(d, prefix=""):
    """One level of dict nesting -> dotted keys; scalars only."""
    out = {}
    for k, v in d.items():
        key = f"{prefix}{k}"
        if isinstance(v, dict):
            if not prefix:          # flatten one level, no deeper
                out.update(_flatten(v, prefix=f"{k}."))
        elif isinstance(v, (list, tuple)):
            continue
        else:
            out[key] = v
    return out


def _fmt(v):
    if isinstance(v, bool):
        return "yes" if v else "no"
    if isinstance(v, float):
        return f"{v:.4g}"
    s = str(v)
    return s if len(s) <= _MAX_STR else s[:_MAX_STR - 1] + "…"


def _rank(col):
    for i, frag in enumerate(_PREFERRED):
        if frag in col.lower():
            return i
    return len(_PREFERRED)


def _pick_columns(rows):
    cols, seen = [], set()
    for r in rows:
        for k in r:
            if k not in seen:
                seen.add(k)
                cols.append(k)
    order = {c: i for i, c in enumerate(cols)}   # stable tiebreak
    cols.sort(key=lambda c: (_rank(c), order[c]))
    return cols[:_MAX_COLS]


def _table(rows):
    cols = _pick_columns(rows)
    if not cols:
        return []
    lines = ["| " + " | ".join(cols) + " |",
             "|" + "|".join("---" for _ in cols) + "|"]
    for r in rows[:_MAX_ROWS]:
        lines.append("| " + " | ".join(
            _fmt(r[c]) if c in r else "" for c in cols) + " |")
    if len(rows) > _MAX_ROWS:
        lines.append(f"\n_...{len(rows) - _MAX_ROWS} more rows in the "
                     "artifact._")
    return lines


def digest_file(path):
    """Markdown lines summarizing one BENCH json (or None to skip)."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        return [f"_unreadable: {e}_"]
    if not isinstance(doc, dict) or "traceEvents" in doc:
        return None                      # raw Chrome trace — viewer input
    lines = []
    failures = doc.get("failures")
    if isinstance(failures, list):
        lines.append("**failures: "
                     + (f"{len(failures)}** ⚠️" if failures else "0**"))
        lines.extend(f"- `{_fmt(f)}`" for f in failures[:5])
        lines.append("")
    rows = doc.get("rows")
    if isinstance(rows, list) and rows and isinstance(rows[0], dict):
        lines.extend(_table([_flatten(r) for r in rows]))
    scalars = _flatten({k: v for k, v in doc.items()
                        if k not in ("rows", "failures", "note")})
    if scalars:
        lines.append("")
        lines.extend(_table([{"key": k, "value": v}
                             for k, v in scalars.items()]))
    return lines


def main(argv=None):
    root = (argv or sys.argv[1:] or ["."])[0]
    paths = sorted(glob.glob(os.path.join(root, "BENCH_*.json")))
    if not paths:
        print(f"_no BENCH_*.json found in {os.path.abspath(root)}_")
        return 0
    print("## Bench digest\n")
    for path in paths:
        body = digest_file(path)
        if body is None:
            continue
        print(f"### {os.path.basename(path)}\n")
        print("\n".join(body))
        print()
    return 0


if __name__ == "__main__":
    sys.exit(main())
