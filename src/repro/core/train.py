"""FF training for the assigned (transformer-family) architectures, plus
the backpropagation baseline.

The FF transformer step is the paper's technique made TPU-native:

  * positive batch = real token sequences; negative batch = corrupted
    sequences (``repro.core.ff``), concatenated on the BATCH axis so both
    FF passes share every matmul (MXU-friendly — the paper runs them as
    two separate passes on CPU nodes).
  * each block's loss is layer-local: ``stop_gradient`` on the block
    input, goodness of the block's residual update (pos high / neg low).
    No gradient ever crosses a block boundary — this is what deletes the
    backward dependency chain the paper's pipeline exploits.
  * the per-block grad AND its Adam update run INSIDE the ``lax.scan``
    over stacked layers. Peak live state is one block's activations +
    grads, independent of depth — no remat needed (the backprop baseline
    needs ``jax.checkpoint``). This is the beyond-paper memory win.
  * the LM head is the paper's softmax classifier: trained with a local
    CE loss that does not propagate into FF blocks (stop-grad features).
  * the embedding is trained with its own local goodness loss (the FF
    "layer 1"); when embeddings are tied, the head CE also reaches the
    table through the unembed — we keep the FF-faithful separation by
    stop-gradding the table in the unembed.

Goodness modes (cfg.ff.goodness):
  "sumsq"    — paper Eq. 1 on the block's residual update (needs neg data)
  "perf_opt" — paper §4.4 Performance-Optimized: local classifier loss
               (CE to next token via the stop-gradded embedding table as
               classifier) — no negative data.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import optim
from repro.core import ff
from repro.models import blocks, common, transformer
from repro.models.mlp import NO_DIST

AUX_WEIGHT = 0.01      # router load-balance weight (local per block)


# ---------------------------------------------------------------------------
# Local losses
# ---------------------------------------------------------------------------

def _block_ff_loss(delta, is_pos, theta):
    """delta: (B2, S, d) the block's residual update."""
    g = ff.mean_goodness(delta)                       # (B2, S)
    return ff.ff_loss_masked(g, is_pos, theta), g


CE_CHUNK = 512     # sequence chunk for vocab-logit computation


def _ce_chunked(h, w_unembed, labels, mask, softcap=0.0):
    """Cross-entropy without materializing (B, S, V) logits.

    Scans over sequence chunks; the chunk body is rematerialized so the
    backward pass never holds more than one chunk's logits either.
    h: (B, S, d); w_unembed: (V, d); labels/mask: (B, S).
    Returns summed CE and summed mask weight.
    """
    B, S, d = h.shape
    c = min(CE_CHUNK, S)
    if S % c:
        pad = c - S % c
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
        S += pad
    nc = S // c

    @jax.checkpoint
    def body(carry, inp):
        hc, lc, mc = inp                    # (B, c, d), (B, c), (B, c)
        logits = jnp.einsum("bsd,vd->bsv", hc.astype(jnp.float32),
                            w_unembed.astype(jnp.float32))
        logits = common.softcap(logits, softcap)
        lp = jax.nn.log_softmax(logits)
        ce = -jnp.take_along_axis(lp, lc[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(ce * mc), None

    r = lambda a: a.reshape(B, nc, c, *a.shape[2:]).transpose(
        1, 0, *range(2, a.ndim + 1))
    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                            (r(h), r(labels), r(mask)))
    return total


def _local_ce(h, embed_sg, labels, mask):
    """Local classifier loss via the (stop-gradded) embedding table."""
    z = common.rms_normalize(h)
    total = _ce_chunked(z, embed_sg, labels, mask)
    return total / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# FF train step
# ---------------------------------------------------------------------------

def make_ff_train_step(cfg, *, dist=NO_DIST, lr=1e-3, seed=0):
    """Returns step_fn(params, opt_state, batch, step) ->
    (params, opt_state, metrics).

    batch: {"tokens": (B, S+1) int32, optional "aux": (B, T, d)}.
    opt_state: optim.adam_init(params).
    """
    perf_opt = cfg.ff.goodness == "perf_opt"
    theta = cfg.ff.theta

    def step_fn(params, opt_state, batch, step):
        tokens = batch["tokens"]
        B, S1 = tokens.shape
        S = S1 - 1
        pos_tok, labels = tokens[:, :-1], tokens[:, 1:]
        key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
        metrics = {}

        if perf_opt:
            x_tok = pos_tok
            is_pos = jnp.ones((B,), jnp.float32)
            lab_all = labels
        else:
            if cfg.ff.neg_mode == "adaptive":
                # self-generated negatives from the current model (no-grad
                # extra forward — the AdaptiveNEG cost the paper reports)
                logits0, _ = transformer.forward(
                    jax.lax.stop_gradient(params), cfg, pos_tok,
                    aux=batch.get("aux"), dist=dist, remat=False)
                neg_tok = ff.adaptive_corrupt_tokens(
                    key, pos_tok, jax.lax.stop_gradient(logits0))
            else:
                nkey = (jax.random.PRNGKey(seed + 1)
                        if cfg.ff.neg_mode == "fixed" else key)
                neg_tok = ff.corrupt_tokens(nkey, pos_tok, cfg.vocab)
            x_tok = jnp.concatenate([pos_tok, neg_tok], axis=0)
            is_pos = jnp.concatenate(
                [jnp.ones((B,)), jnp.zeros((B,))]).astype(jnp.float32)
            lab_all = jnp.concatenate([labels, labels], axis=0)

        aux_in = batch.get("aux")
        if aux_in is not None and x_tok.shape[0] != aux_in.shape[0]:
            aux_in = jnp.concatenate([aux_in, aux_in], axis=0)

        embed_sg = jax.lax.stop_gradient(params["embed"])
        ce_mask = (is_pos[:, None] * jnp.ones((1, S))).astype(jnp.float32)

        # ---- embedding: FF layer 1 (local loss) -------------------------
        def embed_loss(embed):
            h = jnp.take(embed, x_tok, axis=0)
            if perf_opt:
                loss = _local_ce(h, embed_sg, lab_all, ce_mask)
            else:
                g = ff.mean_goodness(common.rms_normalize(h))
                loss = ff.ff_loss_masked(g, is_pos, theta)
            return loss, h

        # grad now, update later (tied archs add the head-CE grad below —
        # the table doubles as the paper's softmax layer)
        (emb_l, x), emb_g = jax.value_and_grad(
            embed_loss, has_aux=True)(params["embed"])
        metrics["loss_embed"] = emb_l

        # ---- encoder (enc-dec archs): FF over stub frame embeddings -----
        cross_src = aux_in
        new_groups = []
        new_m_groups = []
        new_v_groups = []
        ff_losses = []
        g_pos_sum = jnp.zeros(())
        g_neg_sum = jnp.zeros(())

        infos = transformer.group_infos(cfg)

        def make_scan(pattern, ctx):
            def body(carry, leaf):
                x_in = dist.constrain_batch(carry)
                unit_p, unit_m, unit_v = leaf

                def loss_fn(up):
                    h = jax.lax.stop_gradient(x_in)
                    total = jnp.zeros(())
                    gp = jnp.zeros(())
                    gn = jnp.zeros(())
                    for kind, bp in zip(pattern, up):
                        h_sg = jax.lax.stop_gradient(h)
                        y, moe_aux = blocks.block_apply(
                            bp, cfg, kind, h_sg, ctx)
                        if perf_opt:
                            loss = _local_ce(y, embed_sg, lab_all, ce_mask)
                        else:
                            loss, g = _block_ff_loss(y - h_sg, is_pos,
                                                     theta)
                            npos = jnp.maximum(is_pos.sum(), 1.0)
                            gp += (g.mean(1) * is_pos).sum() / npos
                            gn += (g.mean(1) * (1 - is_pos)).sum() / \
                                jnp.maximum((1 - is_pos).sum(), 1.0)
                        total = total + loss + AUX_WEIGHT * moe_aux
                        h = y
                    return total, (h, gp / len(pattern), gn / len(pattern))

                (loss, (y, gp, gn)), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(unit_p)
                new_p, st = optim.adam_update(
                    unit_p, grads, {"m": unit_m, "v": unit_v},
                    lr=lr, step=step)
                y = dist.constrain_batch(jax.lax.stop_gradient(y))
                return y, (new_p, st["m"], st["v"], loss, gp, gn)
            return body

        # encoder first (if any), over aux embeddings
        if cfg.enc_dec:
            xe = aux_in
            for gi, pattern, repeat, is_enc in infos:
                if not is_enc:
                    continue
                ctx = {"causal": False, "dist": dist}
                body = make_scan(pattern, ctx)
                xe, ys = jax.lax.scan(
                    body, xe, (params["groups"][gi],
                               opt_state["m"]["groups"][gi],
                               opt_state["v"]["groups"][gi]))
                new_groups.append(ys[0])
                new_m_groups.append(ys[1])
                new_v_groups.append(ys[2])
                ff_losses.append(ys[3].sum())
                g_pos_sum += ys[4].sum()
                g_neg_sum += ys[5].sum()
            cross_src = common.rms_norm(xe, params["enc_norm"],
                                        cfg.norm_eps)

        # decoder / main stack
        ctx = {"causal": True, "aux": cross_src, "dist": dist}
        for gi, pattern, repeat, is_enc in infos:
            if is_enc:
                continue
            body = make_scan(pattern, ctx)
            x, ys = jax.lax.scan(
                body, x, (params["groups"][gi],
                          opt_state["m"]["groups"][gi],
                          opt_state["v"]["groups"][gi]))
            new_groups.append(ys[0])
            new_m_groups.append(ys[1])
            new_v_groups.append(ys[2])
            ff_losses.append(ys[3].sum())
            g_pos_sum += ys[4].sum()
            g_neg_sum += ys[5].sum()

        # ---- head: the paper's softmax layer (local CE) ------------------
        head_keys = ["final_norm"] + (
            [] if cfg.tie_embeddings else ["lm_head"])
        if cfg.enc_dec:
            head_keys.append("enc_norm")

        # CE is evaluated on the positive half only (negatives carry no
        # next-token signal); sequence-chunked so (B, S, V) logits never
        # materialize. For tied embeddings the table IS the softmax layer
        # (paper §3: trained with local CE), so it receives this grad too.
        x_pos_h = x if perf_opt else x[:B]

        def head_loss(hp):
            h = common.rms_norm(jax.lax.stop_gradient(x_pos_h),
                                hp["final_norm"], cfg.norm_eps)
            w = hp["embed"] if cfg.tie_embeddings else hp["lm_head"].T
            ones = jnp.ones(labels.shape, jnp.float32)
            total = _ce_chunked(h, w, labels, ones,
                                softcap=cfg.logit_softcap)
            return total / labels.size

        hp = {k: params[k] for k in head_keys}
        if cfg.tie_embeddings:
            hp["embed"] = params["embed"]
        ce_l, head_g = jax.value_and_grad(head_loss)(hp)

        # embedding: FF(layer-1) grad + (tied) softmax-layer CE grad
        emb_g_total = emb_g
        if cfg.tie_embeddings:
            emb_g_total = jax.tree.map(jnp.add, emb_g,
                                       head_g.pop("embed"))
            hp.pop("embed")
        new_embed, emb_opt = optim.adam_update(
            params["embed"], emb_g_total,
            {"m": opt_state["m"]["embed"], "v": opt_state["v"]["embed"]},
            lr=lr, step=step)
        new_hp, head_opt = optim.adam_update(
            hp, {k: head_g[k] for k in hp},
            {"m": {k: opt_state["m"][k] for k in hp},
             "v": {k: opt_state["v"][k] for k in hp}},
            lr=lr, step=step)

        # ---- reassemble -----------------------------------------------------
        new_params = dict(params)
        new_params["embed"] = new_embed
        new_params["groups"] = tuple(new_groups)
        for k in new_hp:
            new_params[k] = new_hp[k]
        new_m = dict(opt_state["m"])
        new_v = dict(opt_state["v"])
        new_m["embed"], new_v["embed"] = emb_opt["m"], emb_opt["v"]
        new_m["groups"] = tuple(new_m_groups)
        new_v["groups"] = tuple(new_v_groups)
        for k in new_hp:
            new_m[k] = head_opt["m"][k]
            new_v[k] = head_opt["v"][k]

        n_units = sum(r for _, _, r, _ in infos)
        metrics.update(
            loss_ff=sum(ff_losses) / max(len(ff_losses), 1),
            loss_ce=ce_l,
            goodness_pos=g_pos_sum / n_units,
            goodness_neg=g_neg_sum / n_units,
        )
        return new_params, {"m": new_m, "v": new_v}, metrics

    return step_fn


# ---------------------------------------------------------------------------
# Backprop baseline (the paper's comparison target)
# ---------------------------------------------------------------------------

def make_bp_train_step(cfg, *, dist=NO_DIST, lr=1e-3):
    """Standard end-to-end cross-entropy training step (with remat)."""

    def loss_fn(params, tokens, aux):
        inp, labels = tokens[:, :-1], tokens[:, 1:]
        logits, aux_l = transformer.forward(params, cfg, inp, aux=aux,
                                            dist=dist, remat=True)
        lp = jax.nn.log_softmax(logits)
        ce = -jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
        return jnp.mean(ce) + AUX_WEIGHT * aux_l

    def step_fn(params, opt_state, batch, step):
        loss, grads = jax.value_and_grad(loss_fn)(
            params, batch["tokens"], batch.get("aux"))
        new_p, new_s = optim.adam_update(params, grads, opt_state,
                                         lr=lr, step=step)
        return new_p, new_s, {"loss_ce": loss}

    return step_fn


# ---------------------------------------------------------------------------
# Eval
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg",))
def eval_ce(params, cfg, tokens, aux=None):
    inp, labels = tokens[:, :-1], tokens[:, 1:]
    logits, _ = transformer.forward(params, cfg, inp, aux=aux, remat=False)
    lp = jax.nn.log_softmax(logits)
    ce = -jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(ce)
