"""``repro.api`` — the one training facade for the paper's FF/PFF system.

The paper's point is that ONE chapter-task DAG can be driven by many
schedules; this module is the one entry point that drives it:

    from repro import api, data
    from repro.configs.ff_mlp import FFMLPConfig

    task = data.mnist_like(n_train=2560, n_test=500)
    cfg = FFMLPConfig(layer_sizes=(784, 400, 400), epochs=60, splits=6)

    res = api.fit(cfg, task)                                # sequential
    res = api.fit(cfg, task, backend="federated", num_nodes=4)
    res = api.fit(cfg, task, backend="executor",            # real devices
                  schedule="all_layers", num_nodes=4)
    res = api.fit(cfg, task, backend="simulate",            # event sim
                  schedule="single_layer", num_nodes=4)

Every backend returns the same ``FitResult`` (params, per-task records,
test accuracy, makespan/speedup/utilization when applicable, profile).
Strategy variation — negatives, goodness objective, classifier — is
config-driven through three registries (``api.negatives``,
``api.goodness``, ``api.classifier``); register your own with
``api.register_negatives`` & co and reference it by name in the config.

Backends
--------
sequential  the canonical chapter-schedule trainer (times every task;
            its records feed the simulator and the paper tables).
federated   the same trainer on node-local shards (Federated PFF §4.3).
executor    the REAL multi-device executor: one ``jax.device`` per paper
            node, async dispatch, ``device_put`` hand-off — bit-exact
            vs ``sequential`` (the CI oracle). Needs ``schedule`` and
            ``num_nodes`` <= len(jax.devices()).
simulate    trains sequentially once, then replays the measured task
            timings through the event-driven schedule simulator.
pod         beyond-paper: the PFF pipeline over a (stage, data, model)
            TPU-style mesh for transformer LM configs
            (``repro.core.pff_pod``); ``num_nodes`` = pipeline stages.

Serving (``api.serve`` — ROADMAP item 2's train-while-serving) is the
fourth registry-driven surface: ``api.traffic`` shapes the request
stream (uniform / zipf / bursty; ``api.register_traffic`` adds more),
``repro.serve`` provides the continuous-batching loop, and the executor
hot-publishes each freshly-trained layer into the serving replica
mid-run:

    res = api.serve(cfg, task, traffic="zipf", num_nodes=4)  # train+serve
    res.slo["latency_p99_ms"], res.slo["consistency_violations"]
    res = api.fit(cfg, task, backend="executor", num_nodes=4,
                  serve=api.ServeConfig(traffic="bursty"))   # same, via fit

Deprecated entry points ``pff.train_ff_mlp``, ``pff.train_federated``
and ``pff_exec.run_pff_exec`` delegate here with a DeprecationWarning;
``launch.serve.serve`` (the old transformer decode demo) warns and
delegates to ``launch.serve.lm_decode``.

``python -m repro.api --selftest`` (= ``make api-smoke``) runs every
registered strategy through the sequential backend on a tiny task and
checks the deprecation shims.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro import data as data_lib
from repro.core import ff_mlp, pff, pff_exec, strategies
from repro.kernels import registry as kernel_registry
from repro.obs import trace as obs_trace
from repro.core.faults import (              # re-exported resilience surface
    FaultPlan, ResilienceConfig,
)
from repro.core.strategies import (          # re-exported registry surface
    classifier, goodness, negatives,
    register_classifier, register_goodness, register_negatives,
)
from repro.serve import engine as serve_engine
from repro.serve.engine import ServeConfig   # re-exported serving surface
from repro.serve.traffic import register_traffic, traffic

__all__ = [
    "fit", "simulate", "serve", "FitResult", "ServeResult", "ServeConfig",
    "BACKENDS",
    "negatives", "goodness", "classifier", "traffic",
    "register_negatives", "register_goodness", "register_classifier",
    "register_traffic",
    "FaultPlan", "ResilienceConfig",
]

BACKENDS = ("sequential", "simulate", "executor", "federated", "pod")


@dataclasses.dataclass
class FitResult:
    """What every backend returns. Fields that a backend cannot measure
    stay None (e.g. ``makespan`` for plain sequential training).
    ``raw`` keeps the backend-native result object (TrainResult /
    ExecResult / SimResult / pod history) for the deprecation shims and
    power users."""
    backend: str
    cfg: object
    params: Optional[dict] = None
    schedule: Optional[str] = None
    num_nodes: int = 1
    records: Optional[List[pff.TaskRecord]] = None
    test_acc: Optional[float] = None
    train_acc: Optional[float] = None
    history: list = dataclasses.field(default_factory=list)
    makespan: Optional[float] = None
    speedup: Optional[float] = None
    utilization: Optional[float] = None
    sim: Optional[pff.SimResult] = None
    profile: Optional[dict] = None
    resilience: Optional[dict] = None
    eval_ce: Optional[float] = None         # LM chapter backends: val CE
    serve: Optional["ServeResult"] = None   # fit(serve=ServeConfig(...))
    trace: Optional[object] = None          # obs.trace.Tracer (trace=...)
    raw: object = None


@dataclasses.dataclass
class ServeResult:
    """What ``api.serve`` returns — same field conventions as
    ``FitResult``: a ``records`` list (per-request lifecycle dicts, the
    serving analog of the per-task ``TaskRecord`` list), per-phase
    ``timings``, and a ``.slo`` stats block shaped like
    ``FitResult.resilience`` (one JSON-ready dict of counters and
    percentiles: p50/p99 latency, throughput, shed rate, swap count,
    staleness, consistency violations)."""
    cfg: object
    traffic: str
    schedule: Optional[str] = None          # None = serve-only (static)
    num_nodes: int = 1
    records: Optional[List[dict]] = None
    swaps: Optional[List[dict]] = None      # hot-swap timeline
    slo: Optional[dict] = None
    timings: Optional[dict] = None          # {"serve_s", ["train_s"]}
    accuracy_by_version: Optional[dict] = None
    test_acc: Optional[float] = None        # accuracy over served requests
    fit: Optional[FitResult] = None         # training side (combined mode)
    trace: Optional[object] = None          # obs.trace.Tracer (trace=...)
    raw: object = None                      # serve.engine.EngineResult


def _validate_strategies(cfg):
    """Fail fast with the registry's helpful errors + pairing checks."""
    good = strategies.goodness.get(cfg.goodness_fn)
    strategies.negatives.get(cfg.neg_mode)
    cls = strategies.classifier.get(cfg.classifier)
    impl = ff_mlp.kernel_impl(cfg)
    if impl != "auto":
        # source-of-truth'd from the kernel impl registry, like the
        # strategy names above — a typo'd kernel_impl fails here, not
        # deep inside the first jitted chapter
        kernel_registry.ff_dense.get(impl)
    if cls.requires_goodness and cfg.goodness_fn != cls.requires_goodness:
        raise ValueError(
            f"classifier {cfg.classifier!r} reads parameters trained by "
            f"goodness_fn={cls.requires_goodness!r}, but the config has "
            f"goodness_fn={cfg.goodness_fn!r}")
    return good


def fit(cfg, task=None, *, backend="sequential", schedule=None,
        num_nodes=1, probe_every=0, verbose=False, profile=False,
        devices=None, overlap=True, resilience=None, resume_from=None,
        serve=None, trace=None, comm_time=0.0, steps=40, batch=8,
        seq=64, lr=1e-3, chapters=4, steps_per_chapter=8,
        head_lr=None) -> FitResult:
    """Train ``cfg`` on ``task`` with the chosen backend. See the module
    docstring for the backend table.

    schedule: PFF schedule for the ``executor``/``simulate`` backends
    (default "all_layers"; "sequential" is forced when num_nodes == 1).
    probe_every/verbose: chapter-level accuracy probes (sequential /
    federated backends).
    profile: executor backend — collect per-task records + node busy
    times (blocks after every task; run again without it for makespan).
    devices: executor backend — explicit device list.
    overlap: executor backend — double-buffer the ``device_put``
    weight/negatives hand-off so transfers overlap compute (the
    default; False restores the serialize-on-demand hand-off for A/B
    runs — the weight stream is bit-identical either way).
    resilience: executor backend — a ``repro.core.faults.
    ResilienceConfig``: chapter-granular checkpointing, retry/backoff +
    dead-node degradation, deterministic fault injection, and the
    elastic federated ``membership`` callback. Stats come back on
    ``FitResult.resilience``.
    resume_from: executor backend — a chapter manifest (or its
    directory) written by a previous resilient run; training replays
    the DAG from the next chapter, bit-exactly.
    serve: executor backend — a ``ServeConfig``: run the combined
    train-while-serve mode (the executor hot-publishes every freshly-
    trained layer into a serving replica, which serves the config's
    traffic concurrently). The serving side comes back on
    ``FitResult.serve``; ``api.serve()`` is the same machinery with the
    serving result on top.
    trace: ``True`` or a ``repro.obs.Tracer`` — record an execution
    trace (spans + events + counters) into ``FitResult.trace``; export
    it with ``repro.obs.export.export`` and analyze the executor DAG
    timeline with ``repro.obs.analyze.analyze``. The default tracer
    blocks after each executor task for accurate per-task durations
    (like ``profile=``); pass ``Tracer(block_tasks=False)`` to observe
    with the async overlap intact.
    comm_time: simulate backend — per-DAG-edge cross-node hand-off cost.
    steps/batch/seq/lr: pod backend — pipeline run length and shapes
    (``task`` may be an iterable of token blocks, or None to use the
    synthetic LM corpus).

    Transformer LM configs (``repro.configs.get_config``) on the
    sequential / executor backends run the CHAPTER schedule
    (``core.pff_lm`` — per-block train tasks + a per-chapter head task)
    instead of the FF-MLP path: ``task`` is a ``data.Source`` of token
    blocks (default: the real-text BPE ``data.text_source``), sized by
    ``chapters`` x ``steps_per_chapter`` x ``batch`` x ``seq``;
    ``head_lr`` overrides ``lr`` for the head task. The executor
    backend drives ``pff_exec.LMExecutor`` across ``num_nodes``
    devices, bit-exact vs sequential; quality comes back on
    ``FitResult.eval_ce`` (held-out CE, scored identically for both).
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected one of "
                         f"{BACKENDS}")
    if (resilience is not None or resume_from is not None) \
            and backend != "executor":
        raise ValueError(
            f"resilience/resume_from are executor-backend features "
            f"(chapter checkpoints, fault injection, elastic "
            f"membership); got backend={backend!r}")
    if serve is not None and backend != "executor":
        raise ValueError(
            f"serve= runs the train-while-serve mode, which needs the "
            f"executor backend's live per-layer publication; got "
            f"backend={backend!r}")
    if serve is not None and not isinstance(serve, ServeConfig):
        raise TypeError(f"serve= expects an api.ServeConfig, got "
                        f"{type(serve).__name__}")
    tracer = obs_trace.as_tracer(trace)
    out_trace = tracer if tracer.enabled else None
    if backend == "pod":
        with tracer.span("fit:pod", num_nodes=num_nodes, steps=steps):
            fres = _fit_pod(cfg, task, num_nodes=num_nodes, steps=steps,
                            batch=batch, seq=seq, lr=lr, verbose=verbose)
        fres.trace = out_trace
        return fres

    if hasattr(cfg, "groups") and backend in ("sequential", "executor"):
        # transformer LM config -> the chapter schedule (core.pff_lm),
        # sequential reference or the real LMExecutor
        if resilience is not None or resume_from is not None \
                or serve is not None:
            raise ValueError(
                "LM chapter schedules do not support resilience/"
                "resume_from/serve yet (ROADMAP: unify with lm_decode "
                "serving)")
        fres = _fit_lm_chapters(
            cfg, task, backend=backend, schedule=schedule,
            num_nodes=num_nodes, chapters=chapters,
            steps_per_chapter=steps_per_chapter, batch=batch, seq=seq,
            lr=lr, head_lr=head_lr, devices=devices, overlap=overlap,
            profile=profile, tracer=tracer)
        fres.trace = fres.trace or out_trace
        return fres

    _validate_strategies(cfg)
    if backend == "sequential":
        with tracer.span("fit:sequential"):
            res = pff.run_chapter_schedule(cfg, task,
                                           probe_every=probe_every,
                                           verbose=verbose)
        return FitResult(backend=backend, cfg=cfg, params=res.params,
                         schedule="sequential", num_nodes=1,
                         records=res.records, test_acc=res.test_acc,
                         train_acc=res.train_acc, history=res.history,
                         trace=out_trace, raw=res)

    if backend == "federated":
        with tracer.span("fit:federated", num_nodes=num_nodes):
            res = pff.run_federated_schedule(cfg, task, num_nodes,
                                             probe_every=probe_every,
                                             verbose=verbose)
        return FitResult(backend=backend, cfg=cfg, params=res.params,
                         schedule="federated", num_nodes=num_nodes,
                         records=res.records, test_acc=res.test_acc,
                         train_acc=res.train_acc, history=res.history,
                         trace=out_trace, raw=res)

    schedule = schedule or ("sequential" if num_nodes == 1
                            else "all_layers")
    if backend == "executor":
        ex = pff_exec.PFFExecutor(cfg, task, schedule, num_nodes,
                                  devices=devices, overlap=overlap,
                                  resilience=resilience)
        if serve is not None:
            return _run_combined(cfg, ex, serve, source=None,
                                 resume_from=resume_from,
                                 schedule=schedule, num_nodes=num_nodes,
                                 tracer=tracer).fit
        res = ex.run(profile=profile, resume_from=resume_from,
                     trace=out_trace)
        return FitResult(backend=backend, cfg=cfg, params=res.params,
                         schedule=schedule, num_nodes=num_nodes,
                         records=res.records, test_acc=res.test_acc,
                         makespan=res.makespan,
                         profile=({"node_busy": res.node_busy}
                                  if res.node_busy is not None
                                  else None),
                         resilience=res.resilience,
                         trace=res.trace, raw=res)

    # backend == "simulate": canonical training once, then replay its
    # measured task timings under the schedule's node assignment
    with tracer.span("fit:simulate", schedule=schedule,
                     num_nodes=num_nodes):
        res = pff.run_chapter_schedule(cfg, task, probe_every=probe_every,
                                       verbose=verbose)
        sim = pff.simulate_schedule(res.records, schedule, num_nodes,
                                    comm_time=comm_time)
    return FitResult(backend=backend, cfg=cfg, params=res.params,
                     schedule=schedule, num_nodes=num_nodes,
                     records=res.records, test_acc=res.test_acc,
                     train_acc=res.train_acc, history=res.history,
                     makespan=sim.makespan, speedup=sim.speedup,
                     utilization=sim.utilization, sim=sim,
                     trace=out_trace, raw=res)


# ---------------------------------------------------------------------------
# Serving facade (repro.serve machinery behind api.serve / fit(serve=...))
# ---------------------------------------------------------------------------

def _serve_records(engine_res) -> List[dict]:
    """Per-request lifecycle dicts (JSON-ready) from the engine's
    ``Request`` objects — the ``ServeResult.records`` convention."""
    return [{"id": r.id, "t_arrival": r.t_arrival, "t_admit": r.t_admit,
             "t_done": r.t_done, "latency": r.latency,
             "version": r.version, "pred": r.pred, "label": r.label,
             "correct": (r.pred == r.label) if r.pred is not None
             else None}
            for r in engine_res.requests]


def _serve_result(cfg, sconfig, engine_res, *, schedule=None, num_nodes=1,
                  fit_result=None, tracer=obs_trace.NOOP) -> ServeResult:
    slo = serve_engine.summarize(engine_res)
    return ServeResult(
        cfg=cfg, traffic=sconfig.traffic, schedule=schedule,
        num_nodes=num_nodes, records=_serve_records(engine_res),
        swaps=engine_res.swaps, slo=slo,
        timings=dict(engine_res.timings),
        accuracy_by_version=serve_engine.accuracy_by_version(engine_res),
        test_acc=slo["accuracy"], fit=fit_result,
        trace=tracer if tracer.enabled else None, raw=engine_res)


def _run_combined(cfg, ex, sconfig, *, source, resume_from, schedule,
                  num_nodes, tracer=obs_trace.NOOP) -> ServeResult:
    """Train-while-serve: one executor run with live publication, one
    serve loop, results cross-linked (``ServeResult.fit`` /
    ``FitResult.serve``). One tracer is shared by the serve loop and
    the executor thread, so the trace has a single clock domain."""
    engine_res = serve_engine.train_while_serve(ex, sconfig, source,
                                                resume_from=resume_from,
                                                tracer=tracer)
    res = engine_res.exec_result
    fit_res = FitResult(backend="executor", cfg=cfg, params=res.params,
                        schedule=schedule, num_nodes=num_nodes,
                        records=res.records, test_acc=res.test_acc,
                        makespan=res.makespan, resilience=res.resilience,
                        trace=res.trace, raw=res)
    sres = _serve_result(cfg, sconfig, engine_res, schedule=schedule,
                         num_nodes=num_nodes, fit_result=fit_res,
                         tracer=tracer)
    fit_res.serve = sres
    return sres


def serve(cfg, task=None, *, traffic=None, source=None, params=None,
          schedule=None, num_nodes=1, devices=None, overlap=True,
          resilience=None, resume_from=None, serve_cfg=None, trace=None,
          **knobs) -> ServeResult:
    """Serve the goodness classifier under deterministic open-loop
    traffic — while TRAINING it live on the executor (the default), or
    from a fixed ``params`` snapshot (serve-only replay).

    traffic: a name from the ``api.traffic`` registry (uniform / zipf /
    bursty, or anything added with ``api.register_traffic``).
    source: a ``data.Source`` for request payloads; defaults to the
    task's test split (``data.source_of``).
    params: a trained params dict — serve-only mode: no training
    underneath, one static snapshot at version 0 (``n_requests``
    bounds the run, default 256).
    schedule/num_nodes/devices/overlap/resilience/resume_from: the
    executor knobs, exactly as ``fit(backend="executor")`` takes them
    (combined mode only).
    serve_cfg / **knobs: a ``ServeConfig``, and/or its fields as
    keywords (``rate=...``, ``max_batch=...``, ``max_wait_s=...``,
    ``queue_cap=...``, ``n_requests=...``, ``seed=...``) — keywords win.
    trace: ``True`` or a ``repro.obs.Tracer`` — record admission /
    batch-form / score / swap-install spans (and, in combined mode, the
    executor's task spans on the SAME clock) into ``ServeResult.trace``.
    Combined mode: the default tracer blocks training after every task;
    pass ``Tracer(block_tasks=False)`` to watch serving under the real
    overlapped training load.
    """
    base = serve_cfg if serve_cfg is not None else ServeConfig()
    if traffic is not None:
        knobs["traffic"] = traffic
    valid = {f.name for f in dataclasses.fields(ServeConfig)}
    bad = set(knobs) - valid
    if bad:
        raise TypeError(f"unknown ServeConfig knob(s) {sorted(bad)}; "
                        f"valid: {sorted(valid)}")
    sconfig = dataclasses.replace(base, **knobs)

    good = _validate_strategies(cfg)
    tracer = obs_trace.as_tracer(trace)
    if source is None:
        if task is None:
            raise ValueError("serve needs a task or an explicit "
                             "source= for request payloads")
        source = data_lib.source_of(task)

    if params is not None:
        if sconfig.n_requests is None:
            sconfig = dataclasses.replace(sconfig, n_requests=256)
        engine_res = serve_engine.serve_static(
            params, cfg, source, sconfig,
            eval_mode=good.eval_mode(cfg), impl=ff_mlp.kernel_impl(cfg),
            tracer=tracer)
        return _serve_result(cfg, sconfig, engine_res, tracer=tracer)

    if task is None:
        raise ValueError("train-while-serve needs the training task "
                         "(pass params= for serve-only)")
    schedule = schedule or ("sequential" if num_nodes == 1
                            else "all_layers")
    ex = pff_exec.PFFExecutor(cfg, task, schedule, num_nodes,
                              devices=devices, overlap=overlap,
                              resilience=resilience)
    return _run_combined(cfg, ex, sconfig, source=source,
                         resume_from=resume_from, schedule=schedule,
                         num_nodes=num_nodes, tracer=tracer)


def simulate(result_or_records, schedule, num_nodes,
             **kw) -> pff.SimResult:
    """Replay a training run's task records under another schedule —
    accepts a ``FitResult`` (sequential/federated/simulate backends) or
    a raw record list."""
    records = getattr(result_or_records, "records", result_or_records)
    if records is None:
        raise ValueError(
            "no task records on this result (executor results carry "
            "records only when profiled or traced with a blocking "
            "tracer — fit(..., profile=True) or fit(..., trace=True))")
    return pff.simulate_schedule(records, schedule, num_nodes, **kw)


def _fit_lm_chapters(cfg, source, *, backend, schedule, num_nodes,
                     chapters, steps_per_chapter, batch, seq, lr,
                     head_lr, devices, overlap, profile,
                     tracer=obs_trace.NOOP) -> FitResult:
    """LM chapter-schedule backends (transformer configs): sequential =
    ``pff_lm.train_chapters`` (the oracle), executor =
    ``pff_exec.LMExecutor`` on real devices. Both consume the same
    ``data.Source`` of token blocks through the same
    ``chapter_batches`` stream and are scored by the same held-out
    ``train.eval_ce`` — so the bit-exactness gate extends to the
    reported CE."""
    import time

    import jax.numpy as jnp

    from repro.core import pff_lm
    from repro.core import train as train_lib

    seed = getattr(cfg, "seed", 0) or 0
    if source is None:
        source = data_lib.text_source(vocab=cfg.vocab, seq_len=seq,
                                      seed=seed)
    if backend == "sequential":
        data_iter = pff_lm.chapter_batches(source, batch=batch,
                                           steps=steps_per_chapter)
        with tracer.span("fit:lm_sequential", chapters=chapters):
            t0 = time.perf_counter()
            params, records, losses = pff_lm.train_chapters(
                cfg, data_iter, chapters=chapters,
                steps_per_chapter=steps_per_chapter, lr=lr,
                head_lr=head_lr, seed=seed)
            makespan = time.perf_counter() - t0
        fres = FitResult(backend=backend, cfg=cfg, params=params,
                         schedule="sequential", num_nodes=1,
                         records=records, makespan=makespan,
                         history=[(i + 1, l)
                                  for i, l in enumerate(losses)])
    else:
        schedule = schedule or ("sequential" if num_nodes == 1
                                else "all_layers")
        ex = pff_exec.LMExecutor(
            cfg, source, schedule, num_nodes, chapters=chapters,
            steps_per_chapter=steps_per_chapter, batch=batch, lr=lr,
            head_lr=head_lr, seed=seed, devices=devices, overlap=overlap)
        res = ex.run(profile=profile,
                     trace=tracer if tracer.enabled else None)
        fres = FitResult(backend=backend, cfg=cfg, params=res.params,
                         schedule=schedule, num_nodes=num_nodes,
                         records=res.records, makespan=res.makespan,
                         profile=({"node_busy": res.node_busy}
                                  if res.node_busy is not None
                                  else None),
                         trace=res.trace, raw=res)
    # one eval path for BOTH backends: held-out CE on a fixed val draw
    ev = jnp.asarray(source.blocks("val", 16, seed=321))
    fres.eval_ce = float(train_lib.eval_ce(fres.params, cfg, ev))
    return fres


def _fit_pod(cfg, task, *, num_nodes, steps, batch, seq, lr, verbose):
    """Beyond-paper pod-pipeline backend (transformer LM configs only).

    NOTE: ``pff_pod``'s step function is jitted internally as TWO
    executables (glue, pipeline) — this driver must NOT wrap it in an
    outer jax.jit (jax-0.4.x GSPMD miscompile; see pff_pod docstring).
    """
    import jax
    import jax.numpy as jnp

    from repro import optim
    from repro.core import pff_pod
    from repro.models import transformer

    if not hasattr(cfg, "groups"):
        raise ValueError(
            "backend=\"pod\" expects a transformer LM config "
            "(repro.configs.get_config(...)); FF-MLP configs run on the "
            "sequential/federated/executor/simulate backends")
    stages = num_nodes
    if stages < 1 or stages > len(jax.devices()):
        raise ValueError(f"pod backend needs 1 <= num_nodes <= "
                         f"{len(jax.devices())} devices, got {stages}")
    mesh = jax.make_mesh((stages, 1, 1), ("stage", "data", "model"))
    key = jax.random.PRNGKey(getattr(cfg, "seed", 0) or 0)
    params = transformer.init(key, cfg)
    opt = optim.adam_init(params)
    inflight = pff_pod.init_inflight(cfg, batch, seq, stages=stages)
    step_fn = pff_pod.make_pff_pod_step(cfg, mesh, lr=lr)
    batches = (task if task is not None
               else data_lib.lm_batches(cfg.vocab, batch, seq, steps))
    history = []
    import time
    t0 = time.perf_counter()
    with mesh:
        for i, tokens in enumerate(batches):
            params, opt, inflight, m = step_fn(
                params, opt, {"tokens": jnp.asarray(tokens)}, inflight,
                i + 1)
            history.append((i + 1, float(m["loss_ff"])))
            if verbose and (i + 1) % 10 == 0:
                print(f"  pod step {i + 1}: FF loss "
                      f"{history[-1][1]:.4f}")
    jax.block_until_ready(params)
    makespan = time.perf_counter() - t0
    return FitResult(backend="pod", cfg=cfg, params=params,
                     schedule="pod_pipeline", num_nodes=stages,
                     history=history, makespan=makespan, raw=history)


# ---------------------------------------------------------------------------
# Selftest: every registered strategy x the sequential backend, plus the
# deprecation shims. ``make api-smoke`` runs this.
# ---------------------------------------------------------------------------

def _selftest_cases():
    """One tiny sequential run per registered strategy (deduplicated)."""
    from repro.configs.ff_mlp import FFMLPConfig

    base = dict(layer_sizes=(784, 64, 64), epochs=2, splits=2,
                batch_size=64, seed=0)
    cases = {}
    for name in strategies.negatives.names():
        cases[f"negatives:{name}"] = FFMLPConfig(
            neg_mode=name, classifier="goodness", goodness_fn="sumsq",
            **base)
    for name in strategies.goodness.names():
        cases[f"goodness:{name}"] = FFMLPConfig(
            neg_mode="random", classifier="goodness", goodness_fn=name,
            **base)
    for name in strategies.classifier.names():
        strat = strategies.classifier.get(name)
        cases[f"classifier:{name}"] = FFMLPConfig(
            neg_mode="random", classifier=name,
            goodness_fn=strat.requires_goodness or "sumsq", **base)
    return cases


def _selftest(argv=None):
    import argparse
    import warnings

    import numpy as np

    p = argparse.ArgumentParser(description="repro.api facade selftest")
    p.add_argument("--selftest", action="store_true",
                   help="accepted for symmetry with `make api-smoke`")
    p.parse_args(argv)

    task = data_lib.mnist_like(n_train=256, n_test=128)
    failures = []
    for label, cfg in _selftest_cases().items():
        try:
            res = fit(cfg, task, backend="sequential")
            acc = res.test_acc
            flat = np.concatenate([np.asarray(lp["w"]).ravel()
                                   for lp in res.params["layers"]])
            if not (0.0 <= acc <= 1.0) or not np.all(np.isfinite(flat)):
                failures.append(f"{label}: degenerate result "
                                f"(acc={acc}, finite={np.all(np.isfinite(flat))})")
            print(f"  {label:24s} acc={acc:.3f} "
                  f"records={len(res.records)} OK")
        except Exception as e:                      # noqa: BLE001
            failures.append(f"{label}: {type(e).__name__}: {e}")
            print(f"  {label:24s} FAILED: {e}")

    # deprecated names must still work AND warn
    from repro.configs.ff_mlp import FFMLPConfig
    shim_cfg = FFMLPConfig(layer_sizes=(784, 32), epochs=2, splits=2,
                           neg_mode="random", classifier="goodness",
                           batch_size=64, seed=0)
    shims = (
        ("pff.train_ff_mlp", lambda: pff.train_ff_mlp(shim_cfg, task)),
        ("pff.train_federated",
         lambda: pff.train_federated(shim_cfg, task, 2)),
        ("pff_exec.run_pff_exec",
         lambda: pff_exec.run_pff_exec(shim_cfg, task, "sequential", 1)),
    )
    for name, call in shims:
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            try:
                out = call()
            except Exception as e:                  # noqa: BLE001
                failures.append(f"{name}: {type(e).__name__}: {e}")
                print(f"  shim {name:24s} FAILED: {e}")
                continue
            if not any(issubclass(w.category, DeprecationWarning)
                       for w in caught):
                failures.append(f"{name}: no DeprecationWarning emitted")
            elif out is None:
                failures.append(f"{name}: shim returned None")
            else:
                print(f"  shim {name:24s} warns + delegates OK")

    if failures:
        print("API SELFTEST FAILED:\n  " + "\n  ".join(failures))
        return 1
    print(f"api selftest OK: {len(_selftest_cases())} strategy cases x "
          "sequential backend + deprecation shims")
    return 0


if __name__ == "__main__":
    raise SystemExit(_selftest())
