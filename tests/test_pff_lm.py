"""Chapter-scheduled FF for transformers (the paper's schedule on the
assigned archs): block-local steps must train only their block and the
schedule must produce simulator-compatible records."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import data as data_lib, optim
from repro.configs import get_config
from repro.core import pff, pff_lm
from repro.models import transformer


@pytest.fixture(scope="module")
def setup():
    import dataclasses
    cfg = get_config("qwen2-0.5b").reduced()
    # reduced configs collapse to 1 block; the chapter schedule needs
    # a real stack
    cfg = dataclasses.replace(cfg, num_layers=3,
                              groups=((("attn",), 3),))
    key = jax.random.PRNGKey(0)
    params = transformer.init(key, cfg)
    opt = optim.adam_init(params)
    return cfg, params, opt


def test_block_step_touches_only_its_block(setup):
    cfg, params, opt = setup
    step = pff_lm.make_block_step(cfg, lr=1e-3)
    tokens = jnp.asarray(next(iter(
        data_lib.lm_batches(cfg.vocab, 4, 32, 1))))
    k = 1
    p2, o2, loss = step(params, opt, {"tokens": tokens}, k, 1)
    assert bool(jnp.isfinite(loss))
    g0, g2 = params["groups"][0], p2["groups"][0]
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g2)):
        # block k changed, all others identical
        assert not np.allclose(np.asarray(a[k], np.float32),
                               np.asarray(b[k], np.float32)) or \
            float(jnp.abs(a[k].astype(jnp.float32)).sum()) == 0
        for j in range(a.shape[0]):
            if j != k:
                np.testing.assert_array_equal(
                    np.asarray(a[j], np.float32),
                    np.asarray(b[j], np.float32))
    # embed untouched by block steps
    np.testing.assert_array_equal(np.asarray(params["embed"], np.float32),
                                  np.asarray(p2["embed"], np.float32))


def test_pod_pipeline_step_finite_and_updates(setup):
    """Regression for the pod-pipeline step: the shard_map body must
    pmean grads over 'data' and psum the loss over 'stage' (unsound
    replication claims used to NaN the weights on multi-axis meshes),
    and the split-jit step must run on a trivial 1-device mesh."""
    from repro.core import pff_pod
    cfg, params, opt = setup
    mesh = jax.make_mesh((1, 1, 1), ("stage", "data", "model"))
    step = pff_pod.make_pff_pod_step(cfg, mesh, lr=1e-3)
    B, S = 4, 32
    inflight = pff_pod.init_inflight(cfg, B, S, stages=1)
    with mesh:
        for i, tokens in enumerate(data_lib.lm_batches(cfg.vocab, B, S, 2)):
            params, opt, inflight, m = step(
                params, opt, {"tokens": jnp.asarray(tokens)}, inflight,
                i + 1)
    assert bool(jnp.isfinite(m["loss_ff"]))
    for leaf in jax.tree.leaves(params):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            assert bool(jnp.all(jnp.isfinite(leaf)))


def test_chapter_schedule_records_and_learning(setup):
    cfg, _, _ = setup

    def data_iter(chapter, block):
        return ({"tokens": jnp.asarray(t)} for t in
                data_lib.lm_batches(cfg.vocab, 4, 32, 3,
                                    seed=chapter * 97 + block))

    params, records, losses = pff_lm.train_chapters(
        cfg, data_iter, chapters=3, steps_per_chapter=3, lr=3e-3)
    repeat = cfg.groups[0][1]
    assert len(records) == 3 * repeat
    # losses drop over chapters. Comparing two single (chapter, block)
    # samples is too noisy (block 0 flaked by ~0.025); compare the mean
    # loss of the last chapter against the first instead.
    first = float(np.mean(losses[:repeat]))
    last = float(np.mean(losses[-repeat:]))
    assert last < first
    # records drive the PFF simulator
    sim = pff.simulate_schedule(records, "all_layers", 2)
    assert sim.makespan > 0 and sim.speedup >= 1.0
