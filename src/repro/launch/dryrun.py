"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination against ShapeDtypeStruct inputs, and extract the roofline
terms from the compiled artifact.

The os.environ lines below MUST run before any other import (jax locks
the device count on first init).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
      --shape train_4k [--multi-pod] [--out experiments/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro import sharding
from repro.configs import INPUT_SHAPES, get_config, list_configs
from repro.core import train as train_lib
from repro.launch import hlo_analysis
from repro.launch import specs as specs_lib
from repro.launch.mesh import make_production_mesh
from repro.models import transformer
from repro.models.mlp import Dist

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link

# ---------------------------------------------------------------------------
# Lower + compile one combination
# ---------------------------------------------------------------------------

def make_dist(cfg, mesh, *, batch_fold_model=False):
    """Axis assignment. ``batch_fold_model`` is the §Perf optimization
    for dense archs whose head count does not divide the model axis
    (qwen2: 14 heads vs 16) — tensor parallelism degenerates to 16x
    replication of attention there, so we fold the model axis into the
    batch axes instead (pure DP for activations; weights stay sharded =
    ZeRO-style). Off by default: baselines are recorded without it."""
    ba = sharding.batch_axes(mesh)
    if batch_fold_model:
        ba = ba + ("model",)
    return Dist(mesh=mesh, batch_axes=ba,
                model_axis="model",
                fsdp_axis="data" if cfg.moe is not None else None)


def lower_combo(arch: str, shape_name: str, *, multi_pod=False,
                step_kind=None, lr=1e-3, opts=()):
    """Returns (lowered, meta). step_kind defaults from the shape kind:
    train -> FF train step; prefill -> prefill; decode -> serve_step.
    opts: iterable of optimization names (see §Perf), e.g.
    ("batch_fold_model",)."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    if not specs_lib.combo_is_applicable(cfg, shape_name):
        raise ValueError(f"{arch} x {shape_name}: inapplicable "
                         "(full attention at 500k)")
    mesh = make_production_mesh(multi_pod=multi_pod)
    dist = make_dist(cfg, mesh,
                     batch_fold_model="batch_fold_model" in opts)
    from repro.models import attention as attention_mod
    attention_mod.DEFAULT_CAUSAL_SKIP = "causal_skip" in opts
    attention_mod.PV_BF16 = "pv_bf16" in opts
    kind = step_kind or shape.kind

    p_sds, o_sds = specs_lib.param_specs_abstract(
        cfg, mesh, with_opt=(kind == "train"))

    if kind == "train":
        step_fn = train_lib.make_ff_train_step(cfg, dist=dist, lr=lr)
        batch = specs_lib.train_input_specs(cfg, shape, mesh,
                                            batch_axes=dist.batch_axes)
        step = jax.ShapeDtypeStruct(
            (), jnp.int32,
            sharding=jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec()))
        with mesh:
            lowered = jax.jit(step_fn).lower(p_sds, o_sds, batch, step)
    elif kind == "prefill":
        def fn(params, batch):
            return transformer.prefill(
                params, cfg, batch["tokens"], aux=batch.get("aux"),
                dist=dist, last_only=True)
        batch = specs_lib.prefill_input_specs(cfg, shape, mesh)
        with mesh:
            lowered = jax.jit(fn).lower(p_sds, batch)
    elif kind == "decode":
        def fn(params, caches, tokens, pos):
            return transformer.serve_step(params, cfg, caches, tokens,
                                          pos, dist=dist)
        caches, tokens, pos = specs_lib.decode_input_specs(cfg, shape, mesh)
        with mesh:
            lowered = jax.jit(fn).lower(p_sds, caches, tokens, pos)
    else:
        raise ValueError(kind)

    meta = {"arch": arch, "shape": shape_name, "kind": kind,
            "multi_pod": multi_pod, "mesh": dict(mesh.shape),
            "chips": mesh.size}
    return lowered, meta


def model_flops(cfg, shape, kind):
    """Reference FLOPs: 6*N_active*D (train) / 2*N_active*D (inference)
    plus the attention term 12*B*S^2*(H*hd) per attention layer (times 3
    for train fwd+bwd, halved for causality). This is the 'useful work'
    yardstick for HLO_FLOPs / MODEL_FLOPS."""
    import math
    p_sds = jax.eval_shape(lambda k: transformer.init(k, cfg),
                           jax.random.PRNGKey(0))
    total = sum(math.prod(s.shape) for s in jax.tree.leaves(p_sds))
    active = total
    if cfg.moe is not None:
        m = cfg.moe
        expert_p = 3 * cfg.d_model * m.expert_ff * m.num_experts \
            * cfg.num_layers
        active_expert = expert_p * m.top_k / m.num_experts
        active = total - expert_p + active_expert

    # attention layers and their effective context
    n_attn = 0
    ctx = shape.seq_len
    for pattern, repeat in cfg.groups:
        for kind_b in pattern:
            if kind_b in ("attn", "xdec"):
                n_attn += repeat
            elif kind_b == "local_attn":
                n_attn += repeat * min(
                    (cfg.rglru.window if cfg.rglru else cfg.window or ctx),
                    ctx) / ctx
    if cfg.window:
        ctx = min(cfg.window, ctx)
    hhd = cfg.n_heads * cfg.resolved_head_dim

    B, S = shape.global_batch, shape.seq_len
    if kind == "train":
        # pos+neg concat doubles tokens; FF ~ 3x fwd (fwd + 1-block bwd)
        tokens = 2 * B * S
        attn = 3 * 2 * 2 * tokens * (ctx / 2) * hhd * n_attn / 1
        return 6 * active * tokens + attn
    if kind == "prefill":
        tokens = B * S
        attn = 2 * 2 * tokens * (ctx / 2) * hhd * n_attn
        return 2 * active * tokens + attn
    # decode: 1 token/seq against a ctx-deep cache; enc-dec archs run
    # only the decoder blocks
    if cfg.enc_dec:
        embed_p = cfg.vocab * cfg.d_model * (1 if cfg.tie_embeddings
                                             else 2)
        frac = cfg.num_layers / (cfg.num_layers + cfg.enc_layers)
        active = embed_p + (active - embed_p) * frac
    attn = 2 * 2 * B * ctx * hhd * n_attn
    return 2 * active * B + attn


def analyze_combo(arch, shape_name, *, multi_pod=False, compile_=True,
                  step_kind=None, opts=()):
    t0 = time.time()
    lowered, meta = lower_combo(arch, shape_name, multi_pod=multi_pod,
                                step_kind=step_kind, opts=opts)
    meta["lower_s"] = round(time.time() - t0, 1)
    if opts:
        meta["opts"] = list(opts)
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    chips = meta["chips"]

    if compile_:
        t1 = time.time()
        compiled = lowered.compile()
        meta["compile_s"] = round(time.time() - t1, 1)
        ca = compiled.cost_analysis() or {}
        ma = compiled.memory_analysis()
        hlo = compiled.as_text()
    else:
        ca, ma = {}, None
        hlo = lowered.as_text()

    # trip-count-aware static analysis of the per-device SPMD program
    an = hlo_analysis.analyze(hlo)
    per_dev_flops = an["flops"]
    per_dev_bytes = an["bytes"]
    per_dev_coll = an["collective_bytes"]

    mem = {}
    if ma is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                mem[k] = int(v)

    mflops = model_flops(cfg, shape, meta["kind"])

    res = dict(meta)
    res.update(
        hlo_flops_total=per_dev_flops * chips,
        hlo_bytes_total=per_dev_bytes * chips,
        collective_bytes_per_dev=per_dev_coll,
        collective_by_type=an["collective_by_type"],
        collective_counts=an["collective_counts"],
        memory=mem,
        xla_cost_analysis={k: float(v) for k, v in ca.items()
                           if k in ("flops", "bytes accessed")},
        model_flops=mflops,
        compute_term_s=per_dev_flops / PEAK_FLOPS,
        memory_term_s=per_dev_bytes / HBM_BW,
        collective_term_s=per_dev_coll / ICI_BW,
        flops_utilization=(mflops / (per_dev_flops * chips)
                           if per_dev_flops else 0.0),
    )
    terms = {"compute": res["compute_term_s"],
             "memory": res["memory_term_s"],
             "collective": res["collective_term_s"]}
    res["bottleneck"] = max(terms, key=terms.get)
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-compile", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    combos = []
    if args.all:
        for arch in list_configs():
            cfg = get_config(arch)
            for shape in INPUT_SHAPES:
                if specs_lib.combo_is_applicable(cfg, shape):
                    combos.append((arch, shape, args.multi_pod))
    else:
        combos = [(args.arch, args.shape, args.multi_pod)]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch, shape, mp in combos:
        tag = f"{arch}__{shape}__{'pod2' if mp else 'pod1'}"
        try:
            res = analyze_combo(arch, shape, multi_pod=mp,
                                compile_=not args.no_compile)
            with open(os.path.join(args.out, tag + ".json"), "w") as f:
                json.dump(res, f, indent=1)
            print(f"OK   {tag}: bottleneck={res['bottleneck']} "
                  f"compute={res['compute_term_s']:.4f}s "
                  f"memory={res['memory_term_s']:.4f}s "
                  f"collective={res['collective_term_s']:.4f}s "
                  f"(lower {res['lower_s']}s compile "
                  f"{res.get('compile_s', 0)}s)")
        except Exception as e:  # noqa: BLE001 — report and continue
            failures.append((tag, repr(e)[:200]))
            print(f"FAIL {tag}: {repr(e)[:200]}")
    if failures:
        raise SystemExit(f"{len(failures)} combos failed: "
                         + ", ".join(t for t, _ in failures))
    print("all combos lowered + compiled")


if __name__ == "__main__":
    main()
