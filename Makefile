PY := PYTHONPATH=src python

.PHONY: test lint bench bench-smoke tune-smoke pff-exec-smoke fault-smoke api-smoke serve-smoke trace-smoke lm-exec-smoke bench-digest

test:
	$(PY) -m pytest -q

# Bug-class lint gate (pyflakes + pycodestyle error classes; config in
# pyproject.toml [tool.ruff]). CI installs ruff; locally `pip install
# ruff` first — a missing ruff fails loudly rather than passing silently.
lint:
	$(PY) -m ruff check .

# Facade selftest: every registered negatives/goodness/classifier
# strategy through api.fit's sequential backend on a tiny task, plus
# the deprecated entry points (must import, warn, and delegate).
api-smoke:
	$(PY) -m repro.api --selftest

# Fast perf/correctness gate: FF hot-loop baseline (ref vs fused Pallas)
# + kernel-vs-oracle error budget. Exits non-zero on a regression.
bench-smoke:
	$(PY) -m benchmarks.run --only=ff_hotloop
	$(PY) -m benchmarks.run --only=kernels

# Autotuner gate: tiny measure-many/pick-fastest sweep into a repo-local
# table (REPRO_TUNE_TABLE keeps ~/.cache clean), then asserts the table
# was written, a re-lookup is a pure in-memory memo hit, every winner
# honors the 1e-4 oracle budget, and a poisoned entry falls back to
# default blocks with a warning. Writes BENCH_kernel_tune.json with
# winners as %-of-roofline. Exits non-zero on any breach.
tune-smoke:
	REPRO_TUNE_TABLE=$(CURDIR)/.tune/tune_table.json \
		$(PY) -m benchmarks.run --only=tune

# Real multi-device PFF executor on 4 faked host devices: measured vs
# simulator-predicted speedup (BENCH_pff_exec.json) + weight-stream
# bit-equality gate vs the sequential trainer. Exits non-zero if the
# executor's weights diverge.
pff-exec-smoke:
	XLA_FLAGS=--xla_force_host_platform_device_count=4 \
		$(PY) -m benchmarks.run --only=pff_exec

# LM chapter gate on 4 faked host devices: a tiny qwen2-0.5b (reduced)
# stack chapter-trained on the real-text BPE source through the real
# executor — weight stream bit-exact vs sequential train_chapters
# (all_layers AND single_layer), eval CE within the stated budget of
# the joint-FF step at equal updates, measured-vs-simulated rows
# (BENCH_lm_exec.json). Exits non-zero on divergence or CE breach.
lm-exec-smoke:
	XLA_FLAGS=--xla_force_host_platform_device_count=4 \
		$(PY) -m benchmarks.run --only=lm_exec

# Markdown digest of every BENCH_*.json in the repo root (CI appends
# this to $GITHUB_STEP_SUMMARY; handy locally after `make bench`).
bench-digest:
	$(PY) -m benchmarks.digest

# Executor resilience gate on 4 faked host devices: chapter-checkpoint
# overhead, per-fault recovery cost (crash/delay/drop/corrupt/dead-node)
# and subprocess kill-then-resume for each schedule — every recovery
# path must reproduce the fault-free weight stream bit-exactly
# (BENCH_pff_faults.json). Exits non-zero on divergence.
fault-smoke:
	XLA_FLAGS=--xla_force_host_platform_device_count=4 \
		$(PY) -m benchmarks.run --only=pff_faults

# Serving gate on 4 faked host devices: static-replay determinism +
# p50/p99 latency vs the recorded bound, then train-while-serve
# (all_layers N=4) with live per-layer hot-swap — zero version-vector
# consistency violations, >= 1 swap per chapter, and an accuracy-vs-
# time curve that climbs (BENCH_serve.json). Exits non-zero otherwise.
serve-smoke:
	XLA_FLAGS=--xla_force_host_platform_device_count=4 \
		$(PY) -m benchmarks.run --only=serve

# Observability gate on 4 faked host devices: traced N=4 executor run +
# traced train-while-serve run through the exporter registry and the
# critical-path analyzer. Gates: critical path <= measured makespan <=
# sum of task durations (two-run protocol), prefetch events reconcile
# with the executor's hand-off counters, weights stay bit-exact with
# tracing on, the Chrome export is Perfetto-loadable, and the disabled
# tracer costs < 2% of the makespan (BENCH_trace.json +
# BENCH_trace_timeline.json). Exits non-zero on any breach.
trace-smoke:
	XLA_FLAGS=--xla_force_host_platform_device_count=4 \
		$(PY) -m benchmarks.run --only=trace

# XLA_FLAGS: the pff_exec/pff_faults sections need 4 faked host devices
# (the other sections are device-count agnostic; tier-1 is green at 1
# and 4).
bench:
	XLA_FLAGS=--xla_force_host_platform_device_count=4 \
		$(PY) -m benchmarks.run
