"""Serving example: batched prefill + greedy decode with KV caches on a
reduced assigned arch — the same ``prefill``/``serve_step`` pair the
decode_32k / long_500k dry-runs lower at production shapes.

  PYTHONPATH=src python examples/serve_decode.py [--arch h2o-danube-3-4b]
"""
import argparse

from repro.configs import get_config
from repro.launch.serve import lm_decode

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="h2o-danube-3-4b",
                help="sliding-window arch shows the ring-buffer cache")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=64)
ap.add_argument("--gen", type=int, default=24)
args = ap.parse_args()

cfg = get_config(args.arch).reduced()
print(f"serving reduced {args.arch} "
      f"(window={cfg.window}, kv={cfg.n_kv}/{cfg.n_heads} heads)")
res = lm_decode(cfg, batch=args.batch, prompt_len=args.prompt_len,
                gen=args.gen)
print(f"prefill: {res['prefill_s']:.2f}s   "
      f"decode: {res['decode_s']:.2f}s "
      f"({res['decode_tok_per_s']:.1f} tok/s)")
print("generated token ids (first 2 rows):")
print(res["generated"][:2])
