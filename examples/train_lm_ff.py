"""End-to-end driver: FF-train a ~1M-param reduced TinyLlama for a few
hundred steps on the synthetic LM corpus, with eval CE probes and a
checkpoint. (The paper's technique applied to an assigned architecture.)

  PYTHONPATH=src python examples/train_lm_ff.py [--steps 300]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import checkpoint, data, optim
from repro.configs import get_config
from repro.core import train as train_lib
from repro.models import transformer

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="tinyllama-1.1b")
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=96)
ap.add_argument("--lr", type=float, default=1e-3)
args = ap.parse_args()

cfg = get_config(args.arch).reduced()
key = jax.random.PRNGKey(0)
params = transformer.init(key, cfg)
opt = optim.adam_init(params)
step_fn = jax.jit(train_lib.make_ff_train_step(cfg, lr=args.lr))
eval_tokens = jnp.asarray(next(iter(
    data.lm_batches(cfg.vocab, 16, args.seq, 1, seed=999))))

print(f"FF-training reduced {args.arch} "
      f"({transformer.param_count(params):,} params) "
      f"for {args.steps} steps")
t0 = time.time()
for i, tokens in enumerate(data.lm_batches(
        cfg.vocab, args.batch, args.seq, args.steps, seed=0)):
    params, opt, m = step_fn(params, opt,
                             {"tokens": jnp.asarray(tokens)}, i + 1)
    if (i + 1) % 25 == 0:
        ce = float(train_lib.eval_ce(params, cfg, eval_tokens))
        gap = float(m["goodness_pos"]) - float(m["goodness_neg"])
        print(f"step {i+1:4d}: eval_ce={ce:.3f} goodness_gap={gap:+.4f} "
              f"({time.time() - t0:.0f}s)")

checkpoint.save("experiments/train_lm_ff.npz", params, step=args.steps)
print("checkpoint saved to experiments/train_lm_ff.npz")
