"""Paper-table analogues (Tables 1-5) on the synthetic image tasks.

Every row trains the canonical chapter schedule once (the weight-update
stream is schedule-invariant — see repro/core/pff.py) and derives the
Sequential / Single-Layer / All-Layers wall-clock from the event
simulator over the measured per-task durations. Federated PFF retrains
with node-local shards.

Absolute MNIST numbers are NOT reproducible offline (no MNIST); the
claims validated here are the paper's RELATIVE ones:
  (1) PFF schedules preserve accuracy vs Sequential (identical stream),
  (2) All-Layers > Single-Layer > Sequential in speed,
  (3) AdaptiveNEG > RandomNEG > FixedNEG in accuracy,
  (4) AdaptiveNEG pays a neg-gen cost that All-Layers parallelizes,
  (5) Softmax classifier trains faster at slightly lower accuracy
      (Sequential), and is FASTER in All-Layers,
  (6) Performance-Optimized gives big speedups at small accuracy cost,
  (7) on the harder (CIFAR-like) task the Performance-Optimized /
      Softmax variants dominate Goodness.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

from repro import api, data as data_lib
from repro.configs.ff_mlp import FFMLPConfig
from repro.core import pff

NODES = 4
_LAST_RESULTS = {}


def bench_cfg(task_dim, *, quick=False, **kw):
    # FF needs ~100 epochs to separate (paper: E=100, S=100); the quick
    # profile keeps that but shrinks width/splits.
    hidden = 400 if quick else 500
    layers = 3 if quick else 4
    base = dict(
        layer_sizes=(task_dim,) + (hidden,) * layers,
        epochs=100 if quick else 120,
        splits=5 if quick else 10,
        batch_size=64,
        seed=0,
    )
    base.update(kw)
    return FFMLPConfig(**base)


def run_model(cfg, task, label, results, federated=False):
    t0 = time.time()
    res = api.fit(cfg, task,
                  backend="federated" if federated else "sequential",
                  num_nodes=NODES if federated else 1)
    wall = time.time() - t0
    row = {"model": label, "wall_s": round(wall, 1),
           "test_acc": round(res.test_acc * 100, 2)}
    for sched, n in (("sequential", 1), ("single_layer", NODES),
                     ("all_layers", NODES)):
        sim = api.simulate(res, sched, n)
        row[sched] = {"time_s": round(sim.makespan, 1),
                      "speedup": round(sim.speedup, 2),
                      "util": round(sim.utilization, 2)}
    results.append(row)
    _LAST_RESULTS[label] = res
    print(f"  {label:28s} acc={row['test_acc']:6.2f}% "
          f"seq={row['sequential']['time_s']:7.1f}s "
          f"SL={row['single_layer']['time_s']:7.1f}s "
          f"(x{row['single_layer']['speedup']}) "
          f"AL={row['all_layers']['time_s']:7.1f}s "
          f"(x{row['all_layers']['speedup']})")
    return res


def run_tables(quick=True, out_dir="experiments"):
    n_train = 2560 if quick else 4032
    n_test = 500 if quick else 1000
    results = {"mnist_like": [], "cifar_like": [], "quick": quick}

    print("== Tables 1-4 analogue (mnist-like) ==")
    task = data_lib.mnist_like(n_train=n_train, n_test=n_test)
    rows = results["mnist_like"]
    for neg in ("adaptive", "random", "fixed"):
        cfg = bench_cfg(task.dim, quick=quick, neg_mode=neg,
                        classifier="goodness")
        run_model(cfg, task, f"{neg.capitalize()}NEG-Goodness", rows)
    for neg in ("adaptive", "random"):
        cfg = bench_cfg(task.dim, quick=quick, neg_mode=neg,
                        classifier="softmax")
        run_model(cfg, task, f"{neg.capitalize()}NEG-Softmax", rows)
    cfg = bench_cfg(task.dim, quick=quick, goodness_fn="perf_opt",
                    classifier="goodness")
    run_model(cfg, task, "Performance-Optimized", rows)
    # Federated PFF rotates through node-local shards, so each chapter
    # does 1/N of the gradient work — compensate with N/2x epochs for a
    # comparable update budget (the paper describes Federated PFF in
    # §4.3 without reporting numbers).
    cfg = bench_cfg(task.dim, quick=quick, neg_mode="random",
                    classifier="goodness")
    cfg = dataclasses.replace(cfg, epochs=cfg.epochs * NODES // 2,
                              splits=cfg.splits * 2)
    run_model(cfg, task, "Federated-RandomNEG", rows, federated=True)

    print("== Table 5 analogue (cifar-like) ==")
    ctask = data_lib.cifar_like(n_train=n_train, n_test=n_test)
    crows = results["cifar_like"]
    for label, kw in (
            ("AdaptiveNEG-Goodness", dict(neg_mode="adaptive",
                                          classifier="goodness")),
            ("RandomNEG-Softmax", dict(neg_mode="random",
                                       classifier="softmax")),
            ("Performance-Optimized", dict(goodness_fn="perf_opt"))):
        cfg = bench_cfg(ctask.dim, quick=quick, **kw)
        run_model(cfg, ctask, label, crows)

    # --- schedule scaling (paper: S=100, N=4 -> 3.75x) -------------------
    # The steady-state All-Layers rate is bound by BOTH node throughput
    # (chapter_time / N) and the per-layer weight chain (max layer
    # time): speedup <= chapter / max(chapter/N, max_layer). Our quick
    # profile's 400-wide hidden makes layer 0 (784x400) the largest ->
    # chain-bound ~2.3x, a real property of thin networks. The paper's
    # [784, 2000x4] has layer 0 SMALLER than the hidden layers (0.39x),
    # which is what allows its 3.75x. We therefore also replay the
    # simulator with paper-proportioned task costs (layer-param ratios
    # of [784x2000, 2000x2000 x3], AdaptiveNEG neg-gen at the paper's
    # measured 0.55x chapter fraction — Tables 1 vs RandomNEG timing).
    print("== Schedule scaling (simulator, paper-proportioned costs) ==")
    t_layers = [784 * 2000] + [2000 * 2000] * 3
    u = 1.0 / t_layers[1]
    t_layers = [t * u for t in t_layers]
    t_neg = 0.55 * sum(t_layers)
    scaling = {}
    for S in (10, 20, 50, 100):
        recs = []
        for c in range(S):
            for k, t in enumerate(t_layers):
                recs.append(pff.TaskRecord("train", k, c, t))
            recs.append(pff.TaskRecord("neg_gen", -1, c, t_neg))
        sim = pff.simulate_schedule(recs, "all_layers", NODES)
        sim_sl = pff.simulate_schedule(recs, "single_layer", NODES)
        scaling[S] = {"all_layers": round(sim.speedup, 2),
                      "single_layer": round(sim_sl.speedup, 2),
                      "util": round(sim.utilization, 2)}
        print(f"  S={S:3d}: All-Layers x{sim.speedup:.2f} "
              f"(util {sim.utilization:.2f})  "
              f"Single-Layer x{sim_sl.speedup:.2f}   "
              f"[paper: 3.75x / 2.13x at S=100]")
    results["schedule_scaling"] = scaling

    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "paper_tables.json")
    with open(path, "w") as f:
        json.dump(results, f, indent=1)
    print("saved", path)
    _check_claims(results)
    return results


def _check_claims(results):
    rows = {r["model"]: r for r in results["mnist_like"]}
    checks = []

    def add(name, ok):
        checks.append((name, bool(ok)))

    g = {k: rows[k] for k in rows if "Goodness" in k}
    if "AdaptiveNEG-Goodness" in rows and "FixedNEG-Goodness" in rows:
        add("AdaptiveNEG >= FixedNEG accuracy",
            rows["AdaptiveNEG-Goodness"]["test_acc"]
            >= rows["FixedNEG-Goodness"]["test_acc"] - 0.5)
    for r in rows.values():
        add(f"{r['model']}: All-Layers faster than Sequential",
            r["all_layers"]["time_s"] < r["sequential"]["time_s"])
        add(f"{r['model']}: speedup <= {NODES}",
            r["all_layers"]["speedup"] <= NODES + 1e-6)
    del g
    print("\nclaim checks:")
    for name, ok in checks:
        print(f"  [{'PASS' if ok else 'FAIL'}] {name}")


if __name__ == "__main__":
    import sys
    run_tables(quick="--full" not in sys.argv)
