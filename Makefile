PY := PYTHONPATH=src python

.PHONY: test bench bench-smoke

test:
	$(PY) -m pytest -q

# Fast perf/correctness gate: FF hot-loop baseline (ref vs fused Pallas)
# + kernel-vs-oracle error budget. Exits non-zero on a regression.
bench-smoke:
	$(PY) -m benchmarks.run --only=ff_hotloop
	$(PY) -m benchmarks.run --only=kernels

bench:
	$(PY) -m benchmarks.run
