"""Executor resilience under deterministic faults: cost + correctness.

Measures what the fault-tolerance layer (``repro.core.faults`` +
``PFFExecutor(resilience=...)``) actually costs and proves what it
promises, writing ``BENCH_pff_faults.json`` (``make fault-smoke``):

  1. checkpoint overhead — the all_layers N=4 run with chapter-granular
     manifests on vs off (warm caches both ways): total and per-chapter
     checkpoint seconds (the device->host drain + atomic .npz write),
     then a resume from the last manifest gated BIT-EXACT against the
     uninterrupted sequential weight stream.
  2. per-fault recovery cost — one warm all_layers N=4 run per named
     plan (crash_once / delay_node / drop_handoff / corrupt_handoff /
     dead_node): makespan delta vs the fault-free run, retry /
     reassignment / hand-off counters, and the bit-exactness gate (every
     one of these recovery paths must reproduce the fault-free weight
     stream — that is the point of entry-time crash injection, version/
     integrity-gated hand-off and device reassignment).
  3. kill-then-resume — for each schedule in {all_layers, single_layer,
     federated} a SUBPROCESS run is hard-killed mid-chapter
     (``os._exit`` via the ``kill_mid`` plan, exit code
     ``faults.KILL_EXIT``), then a second subprocess resumes from the
     surviving manifests; the resumed process itself gates its final
     weights bit-exact against the fault-free reference (the
     ``pff_exec`` CLI's ``--fault-plan``/``--resume-from`` path — the
     same one ``tests/test_pff_faults.py`` drives).

Needs >= 4 devices (export
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` before jax is
imported; this module sets it when imported first, and ``make
fault-smoke`` always does). With fewer devices an existing
``BENCH_pff_faults.json`` is kept rather than clobbered — same policy
as ``benchmarks/pff_exec.py``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time

if "jax" not in sys.modules:                       # pragma: no cover
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax

from repro import api, data as data_lib
from repro.configs.ff_mlp import FFMLPConfig
from repro.core import faults, pff_exec

_SRC = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                    os.pardir, "src")

FAULT_ROWS = ("crash_once", "delay_node", "drop_handoff",
              "corrupt_handoff", "dead_node")
KILL_SCHEDULES = (("all_layers", 4), ("single_layer", 2),
                  ("federated", 4))


def _fit(cfg, task, devices, *, resilience=None, resume_from=None):
    return api.fit(cfg, task, backend="executor", schedule="all_layers",
                   num_nodes=4, devices=devices, resilience=resilience,
                   resume_from=resume_from)


def _bit_gate(label, ref, res, failures):
    ok = pff_exec.params_bit_equal(ref.params, res.params)
    if not ok:
        failures.append(f"{label}: weight stream diverged from the "
                        "fault-free reference")
    return ok


def _kill_resume_row(schedule, nodes, splits, n_train, failures):
    """Hard-kill a CLI run mid-chapter, resume it, parse the verdicts."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    base = [sys.executable, "-m", "repro.core.pff_exec",
            "--schedule", schedule, "--nodes", str(nodes),
            "--splits", str(splits), "--n-train", str(n_train)]
    row = {"schedule": schedule, "nodes": nodes}
    with tempfile.TemporaryDirectory() as td:
        t0 = time.perf_counter()
        killed = subprocess.run(
            base + ["--fault-plan", "kill_mid", "--checkpoint-dir", td],
            capture_output=True, text=True, env=env, timeout=540)
        row["killed_s"] = time.perf_counter() - t0
        row["kill_exit"] = killed.returncode
        if killed.returncode != faults.KILL_EXIT:
            failures.append(
                f"kill-resume {schedule}: expected the injected kill "
                f"(exit {faults.KILL_EXIT}), got {killed.returncode}:\n"
                f"{killed.stdout}\n{killed.stderr}")
            return row
        manifests = sorted(os.listdir(td))
        row["manifests_at_kill"] = manifests
        if not manifests:
            failures.append(f"kill-resume {schedule}: no chapter "
                            "manifest survived the kill")
            return row
        t0 = time.perf_counter()
        resumed = subprocess.run(
            base + ["--resume-from", td], capture_output=True, text=True,
            env=env, timeout=540)
        row["resume_s"] = time.perf_counter() - t0
        row["resume_exit"] = resumed.returncode
        # the resumed CLI gates params_bit_equal vs the fault-free
        # reference itself and exits non-zero on divergence
        row["resume_bit_exact"] = resumed.returncode == 0
        if resumed.returncode != 0:
            failures.append(
                f"kill-resume {schedule}: resumed run failed or "
                f"diverged (exit {resumed.returncode}):\n"
                f"{resumed.stdout}\n{resumed.stderr}")
    return row


def run(quick=True, out_path=None):
    if out_path is None:
        out_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                os.pardir, "BENCH_pff_faults.json")
    splits, n_train = (4, 520) if quick else (8, 1000)
    cfg = FFMLPConfig(layer_sizes=(784, 128, 128), epochs=splits * 2,
                      splits=splits, neg_mode="random",
                      classifier="goodness", goodness_fn="sumsq",
                      batch_size=64, seed=0)
    task = data_lib.mnist_like(n_train=n_train, n_test=200)
    devices = jax.devices()
    n_dev = len(devices)
    print(f"devices: {n_dev} x {devices[0].platform}")
    results = {
        "config": {"n_train": n_train, "splits": splits,
                   "layer_sizes": list(cfg.layer_sizes),
                   "backend": jax.default_backend(), "devices": n_dev,
                   "cpu_count": os.cpu_count()},
        "failures": [],
    }
    if n_dev < 4:
        msg = (f"needs 4 devices, found {n_dev} — set XLA_FLAGS="
               "--xla_force_host_platform_device_count=4 "
               "(see make fault-smoke)")
        print(msg)
        if os.path.exists(out_path):
            print(f"keeping existing {os.path.normpath(out_path)}")
        else:
            results["note"] = msg
            with open(out_path, "w") as f:
                json.dump(results, f, indent=2)
        return results
    failures = results["failures"]

    ref = api.fit(cfg, task, backend="sequential")
    _fit(cfg, task, devices)                      # compile warm-up
    base = _fit(cfg, task, devices)               # warm fault-free run
    _bit_gate("baseline", ref, base, failures)
    print(f"fault-free all_layers N=4 makespan {base.makespan:.2f}s "
          f"acc {base.test_acc:.4f}")

    # ---- 1. checkpoint overhead + resume --------------------------------
    with tempfile.TemporaryDirectory() as td:
        rc = faults.ResilienceConfig(checkpoint_dir=td, keep_last=splits)
        _fit(cfg, task, devices, resilience=rc)   # warm (incl. writes)
        ck = _fit(cfg, task, devices, resilience=rc)
        st = ck.resilience
        _bit_gate("checkpointing", ref, ck, failures)
        # resume from the second-newest manifest so the final chapter is
        # actually REPLAYED through the DAG (not just restored)
        resumed = _fit(cfg, task, devices, resume_from=os.path.join(
            td, f"pff_chapter_{splits - 2:04d}.npz"))
        _bit_gate("resume", ref, resumed, failures)
        if resumed.resilience["resumed_from_chapter"] != splits - 2:
            failures.append("resume restored the wrong manifest")
        results["checkpoint"] = {
            "makespan_s_off": base.makespan,
            "makespan_s_on": ck.makespan,
            "overhead_s": ck.makespan - base.makespan,
            "checkpoints_written": st["checkpoints_written"],
            "checkpoint_time_s": st["checkpoint_time_s"],
            "checkpoint_time_s_per_chapter":
                st["checkpoint_time_s"] / max(st["checkpoints_written"], 1),
            "restore_time_s": resumed.resilience["restore_time_s"],
        }
        print(f"checkpointing: +{results['checkpoint']['overhead_s']:.2f}s"
              f" wall ({st['checkpoints_written']} manifests, "
              f"{st['checkpoint_time_s']:.2f}s in save, restore "
              f"{results['checkpoint']['restore_time_s']:.3f}s)")

    # ---- 2. per-fault recovery cost -------------------------------------
    results["faults"] = []
    for name in FAULT_ROWS:
        plan = faults.named_plan(name, splits=splits,
                                 n_layers=len(cfg.layer_sizes) - 1,
                                 num_nodes=4)
        rc = faults.ResilienceConfig(fault_plan=plan,
                                     backoff_base_s=0.01)
        res = _fit(cfg, task, devices, resilience=rc)
        st = res.resilience
        bit = _bit_gate(f"fault {name}", ref, res, failures)
        row = {"plan": name, "makespan_s": res.makespan,
               "recovery_cost_s": res.makespan - base.makespan,
               "retries": st["retries"],
               "reassignments": st["reassignments"],
               "dead_nodes": st["dead_nodes"],
               "recovery_time_s": st["recovery_time_s"],
               "faults_injected": st["faults_injected"],
               "handoff": res.raw.handoff,
               "weights_bit_exact": bit}
        results["faults"].append(row)
        print(f"{name:>16}: makespan {res.makespan:6.2f}s "
              f"(+{row['recovery_cost_s']:5.2f}s) "
              f"injected={st['faults_injected']} "
              + ("bit-exact" if bit else "DIVERGED"))

    # ---- 3. kill mid-chapter, resume, bit-exact (subprocess pairs) ------
    results["kill_resume"] = []
    for schedule, nodes in KILL_SCHEDULES:
        row = _kill_resume_row(schedule, nodes, splits, n_train, failures)
        results["kill_resume"].append(row)
        print(f"kill+resume {schedule:>13} N={nodes}: "
              f"kill_exit={row.get('kill_exit')} "
              f"resume_exit={row.get('resume_exit', '-')} "
              f"manifests={len(row.get('manifests_at_kill', []))} "
              + ("bit-exact" if row.get("resume_bit_exact") else "FAIL"))

    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {os.path.normpath(out_path)}")
    return results
