"""Chapter-scheduled FF for transformers (the paper's schedule on the
assigned archs): block-local steps must train only their block, the
per-chapter head task must actually move the head weights, the schedule
must produce simulator-compatible records, and the REAL executor must
reproduce the sequential weight stream bit-exactly on the BPE text
source (subprocess matrix — conftest keeps the in-process runner on one
device)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import data as data_lib, optim
from repro.configs import get_config
from repro.core import pff, pff_lm
from repro.models import transformer

_SRC = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                    os.pardir, "src")


@pytest.fixture(scope="module")
def setup():
    import dataclasses
    cfg = get_config("qwen2-0.5b").reduced()
    # reduced configs collapse to 1 block; the chapter schedule needs
    # a real stack
    cfg = dataclasses.replace(cfg, num_layers=3,
                              groups=((("attn",), 3),))
    key = jax.random.PRNGKey(0)
    params = transformer.init(key, cfg)
    opt = optim.adam_init(params)
    return cfg, params, opt


def test_block_step_touches_only_its_block(setup):
    cfg, params, opt = setup
    step = pff_lm.make_block_step(cfg, lr=1e-3)
    tokens = jnp.asarray(next(iter(
        data_lib.lm_batches(cfg.vocab, 4, 32, 1))))
    k = 1
    p2, o2, loss = step(params, opt, {"tokens": tokens}, k, 1)
    assert bool(jnp.isfinite(loss))
    g0, g2 = params["groups"][0], p2["groups"][0]
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g2)):
        # block k changed, all others identical
        assert not np.allclose(np.asarray(a[k], np.float32),
                               np.asarray(b[k], np.float32)) or \
            float(jnp.abs(a[k].astype(jnp.float32)).sum()) == 0
        for j in range(a.shape[0]):
            if j != k:
                np.testing.assert_array_equal(
                    np.asarray(a[j], np.float32),
                    np.asarray(b[j], np.float32))
    # embed untouched by block steps
    np.testing.assert_array_equal(np.asarray(params["embed"], np.float32),
                                  np.asarray(p2["embed"], np.float32))


def test_pod_pipeline_step_finite_and_updates(setup):
    """Regression for the pod-pipeline step: the shard_map body must
    pmean grads over 'data' and psum the loss over 'stage' (unsound
    replication claims used to NaN the weights on multi-axis meshes),
    and the split-jit step must run on a trivial 1-device mesh."""
    from repro.core import pff_pod
    cfg, params, opt = setup
    mesh = jax.make_mesh((1, 1, 1), ("stage", "data", "model"))
    step = pff_pod.make_pff_pod_step(cfg, mesh, lr=1e-3)
    B, S = 4, 32
    inflight = pff_pod.init_inflight(cfg, B, S, stages=1)
    with mesh:
        for i, tokens in enumerate(data_lib.lm_batches(cfg.vocab, B, S, 2)):
            params, opt, inflight, m = step(
                params, opt, {"tokens": jnp.asarray(tokens)}, inflight,
                i + 1)
    assert bool(jnp.isfinite(m["loss_ff"]))
    for leaf in jax.tree.leaves(params):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            assert bool(jnp.all(jnp.isfinite(leaf)))


def test_chapter_schedule_records_and_learning(setup):
    cfg, _, _ = setup

    def data_iter(chapter, block):
        return ({"tokens": jnp.asarray(t)} for t in
                data_lib.lm_batches(cfg.vocab, 4, 32, 3,
                                    seed=chapter * 97 + block))

    params, records, losses = pff_lm.train_chapters(
        cfg, data_iter, chapters=3, steps_per_chapter=3, lr=3e-3)
    repeat = cfg.groups[0][1]
    # per chapter: one train record per block + ONE head record
    assert len(records) == 3 * (repeat + 1)
    assert sum(r.kind == "head" for r in records) == 3
    assert all(r.layer == repeat for r in records if r.kind == "head")
    # losses (train-FF only — the head's CE lives on a different scale)
    assert len(losses) == 3 * repeat
    # losses drop over chapters. Comparing two single (chapter, block)
    # samples is too noisy (block 0 flaked by ~0.025); compare the mean
    # loss of the last chapter against the first instead.
    first = float(np.mean(losses[:repeat]))
    last = float(np.mean(losses[-repeat:]))
    assert last < first
    # records drive the PFF simulator
    sim = pff.simulate_schedule(records, "all_layers", 2)
    assert sim.makespan > 0 and sim.speedup >= 1.0


def test_chapter_head_actually_updates(setup):
    """Regression: train_chapters used to build the head step but never
    run it (the head_lr knob was dead and final_norm/the softmax weights
    stayed at init). Every head parameter must move."""
    cfg, params0, _ = setup

    def data_iter(chapter, block):
        return ({"tokens": jnp.asarray(t)} for t in
                data_lib.lm_batches(cfg.vocab, 4, 32, 2,
                                    seed=chapter * 97 + block))

    params, _, _ = pff_lm.train_chapters(
        cfg, data_iter, chapters=2, steps_per_chapter=2, lr=3e-3)
    for name in pff_lm.head_param_names(cfg):
        a = np.asarray(params0[name], np.float32)
        b = np.asarray(params[name], np.float32)
        assert not np.array_equal(a, b), f"head param {name!r} never " \
            "updated — the per-chapter head task did not run"


def test_text_source_bpe_round_trip_and_determinism():
    """The real-text pipeline: BPE encode/decode is the identity on the
    checked-in corpus, token blocks regenerate deterministically per
    (seed, split) — the purity the executor's hand-off relies on (data
    never crosses nodes) — and splits don't leak into each other."""
    from repro.data import encoder as encoder_lib
    enc = encoder_lib.default_encoder(512)
    text = encoder_lib.corpus_text()
    ids = enc.encode(text)
    assert enc.decode(ids) == text
    assert max(ids) < 512 and min(ids) >= 0
    assert len(ids) < len(text)          # merges actually compress

    src = data_lib.text_source(vocab=512, seq_len=16, seed=0)
    a = src.blocks("train", 8, seed=3)
    b = src.blocks("train", 8, seed=3)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert np.asarray(a).shape == (8, 17)     # seq_len + 1 (shift pair)
    c = src.blocks("train", 8, seed=4)
    assert not np.array_equal(np.asarray(a), np.asarray(c))
    # val draws from the holdout tail — different region than train
    v = src.blocks("val", 8, seed=3)
    assert not np.array_equal(np.asarray(a), np.asarray(v))
    # Source protocol adapter: (x = first seq_len tokens, y = next)
    x, y = src.sample("train", 4, seed=1)
    assert np.asarray(x).shape == (4, 16)
    assert np.asarray(y).shape == (4,) and y.dtype == np.int32


def test_lm_executor_bit_exact_matrix():
    """The tentpole gate: pff_exec.LMExecutor on 4 faked devices must
    reproduce train_chapters' weight stream bit-exactly on the BPE text
    source for All-Layers AND Single-Layer (plus the overlap on/off
    A-B). One subprocess sweeps repro.core.pff_exec._LM_MATRIX."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m", "repro.core.pff_exec", "--lm-matrix"],
        capture_output=True, text=True, env=env, timeout=540)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "executor chapter schedule bit-exact" in r.stdout
