"""Trainable fused FF layer: custom_vjp around the ff_dense Pallas kernel.

Forward is the existing fused matmul -> ReLU -> goodness kernel
(``ff_dense.py``); this module adds the missing piece that makes it the
*training-time* engine rather than a benchmark curiosity: a fused Pallas
backward kernel, so ``jax.grad`` of the FF objective runs entirely on
the fused path.

Math. With y = relu(x @ w + b) and g = sum(y^2, axis=-1), the cotangents
(dy_out, dg) of (y, g) combine into a single post-activation gradient

    dy = (dy_out + 2 * y * dg[:, None]) * 1[y > 0]

(1[y > 0] is the ReLU mask — y > 0 iff the pre-activation was > 0), and

    dw = x^T @ dy      db = sum_rows(dy)      dx = dy @ w^T.

The backward kernel fuses the dy construction with all three products so
the (M, N) dy never makes an HBM round-trip: grid (K/bk, M/bm) with M
innermost, dy rebuilt per K-block from the resident y/dy_out/dg row
blocks (cheap VPU work traded for the HBM traffic of materializing dy).
dw accumulates across the inner M steps into the same resident (bk, N)
block; db accumulates on the kb == 0 passes. N is streamed whole per
block (padded to a lane multiple) — for the paper's 2000-wide layers a
(128, 2048) f32 block is ~1 MB.

Non-tile-aligned shapes are zero-padded exactly like the forward kernel;
zero rows/cols of x/w/y/dy contribute zero to every product, so slicing
the outputs back is exact.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ff_dense import NORM_EPS, ff_dense as _ff_dense_fwd


def _bwd_kernel(x_ref, w_ref, y_ref, dyo_ref, dg_ref,
                dx_ref, dw_ref, db_ref):
    kb = pl.program_id(0)
    i = pl.program_id(1)
    y = y_ref[...].astype(jnp.float32)
    dy = dyo_ref[...].astype(jnp.float32) + 2.0 * y * dg_ref[...][:, None]
    dy = jnp.where(y > 0.0, dy, 0.0)                      # (bm, N)

    dx_ref[...] = jnp.dot(
        dy, w_ref[...].astype(jnp.float32).T,
        preferred_element_type=jnp.float32).astype(dx_ref.dtype)

    dw_part = jnp.dot(x_ref[...].astype(jnp.float32).T, dy,
                      preferred_element_type=jnp.float32)  # (bk, N)

    @pl.when(i == 0)
    def _init_dw():
        dw_ref[...] = dw_part.astype(dw_ref.dtype)

    @pl.when(i != 0)
    def _acc_dw():
        dw_ref[...] = dw_ref[...] + dw_part.astype(dw_ref.dtype)

    db_part = jnp.sum(dy, axis=0)

    @pl.when((kb == 0) & (i == 0))
    def _init_db():
        db_ref[...] = db_part

    @pl.when((kb == 0) & (i != 0))
    def _acc_db():
        db_ref[...] = db_ref[...] + db_part


@functools.partial(jax.jit, static_argnames=("bm", "bk", "interpret"))
def ff_dense_bwd(x, w, y, dy_out, dg, *, bm=128, bk=256, interpret=True):
    """Fused backward: (x, w, y, dL/dy, dL/dg) -> (dx, dw, db)."""
    M, K = x.shape
    N = w.shape[1]
    bm = min(bm, M)
    bk = min(bk, K)
    Mp = -(-M // bm) * bm
    Kp = -(-K // bk) * bk
    Np = -(-N // 128) * 128
    if Mp != M or Kp != K or Np != N:
        x = jnp.pad(x, ((0, Mp - M), (0, Kp - K)))
        w = jnp.pad(w, ((0, Kp - K), (0, Np - N)))
        y = jnp.pad(y, ((0, Mp - M), (0, Np - N)))
        dy_out = jnp.pad(dy_out, ((0, Mp - M), (0, Np - N)))
        dg = jnp.pad(dg, (0, Mp - M))

    grid = (Kp // bk, Mp // bm)          # M innermost: dw stays resident
    dx, dw, db = pl.pallas_call(
        _bwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda kb, i: (i, kb)),   # x
            pl.BlockSpec((bk, Np), lambda kb, i: (kb, 0)),   # w
            pl.BlockSpec((bm, Np), lambda kb, i: (i, 0)),    # y
            pl.BlockSpec((bm, Np), lambda kb, i: (i, 0)),    # dy_out
            pl.BlockSpec((bm,), lambda kb, i: (i,)),         # dg
        ],
        out_specs=[
            pl.BlockSpec((bm, bk), lambda kb, i: (i, kb)),   # dx
            pl.BlockSpec((bk, Np), lambda kb, i: (kb, 0)),   # dw
            pl.BlockSpec((Np,), lambda kb, i: (0,)),         # db
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Mp, Kp), x.dtype),
            jax.ShapeDtypeStruct((Kp, Np), w.dtype),
            jax.ShapeDtypeStruct((Np,), jnp.float32),
        ],
        interpret=interpret,
    )(x, w, y, dy_out, dg)
    return dx[:M, :K], dw[:K, :N], db[:N]


def _split_blocks(blocks):
    """Tuned block shapes -> (forward kwargs, backward kwargs).

    ``blocks`` is None (kernel defaults) or an autotuner-shaped
    ``(bm, bn, bk)`` tuple with None holes meaning "default": bm/bn tile
    the forward grid, bm/bk the backward one (the backward streams N
    whole, so bn never reaches it; the forward streams K whole, so bk
    never reaches it — see each kernel's docstring).
    """
    if blocks is None:
        return {}, {}
    bm, bn, bk = blocks
    fwd = {k: v for k, v in (("bm", bm), ("bn", bn)) if v}
    bwd = {k: v for k, v in (("bm", bm), ("bk", bk)) if v}
    return fwd, bwd


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def ff_dense_vjp(x, w, b, interpret=True, blocks=None):
    """Differentiable fused FF layer. Returns (y (M, N), goodness (M,)).

    ``interpret`` and ``blocks`` must be passed positionally (custom_vjp
    nondiff args); use interpret=True everywhere except on a real TPU.
    ``blocks`` is an optional autotuned ``(bm, bn, bk)`` tuple (from
    ``kernels.autotune``) applied to BOTH the forward and the fused
    backward kernel; None means the MXU-aligned defaults.
    """
    fwd_kw, _ = _split_blocks(blocks)
    return _ff_dense_fwd(x, w, b, interpret=interpret, **fwd_kw)


def _ff_dense_vjp_fwd(x, w, b, interpret, blocks):
    fwd_kw, _ = _split_blocks(blocks)
    y, g = _ff_dense_fwd(x, w, b, interpret=interpret, **fwd_kw)
    return (y, g), (x, w, b, y)


def _ff_dense_vjp_bwd(interpret, blocks, res, cts):
    x, w, b, y = res
    dy_out, dg = cts
    _, bwd_kw = _split_blocks(blocks)
    dx, dw, db = ff_dense_bwd(x, w, y, dy_out, dg, interpret=interpret,
                              **bwd_kw)
    return dx, dw, db.astype(b.dtype)


ff_dense_vjp.defvjp(_ff_dense_vjp_fwd, _ff_dense_vjp_bwd)


# ---------------------------------------------------------------------------
# Normed variant: the kernel's fused inter-layer norm epilogue,
# differentiable. yn = y / (sqrt(g) + eps) with g = sum(y^2, -1).
#
# Backward math. Write s = sqrt(g), u = 1 / (s + eps), so yn = y * u and
# u depends on y only through g. For cotangents (dyn, dg_ct) the chain
# rule through the normalizer gives the POST-ReLU gradient
#
#     dy = dyn * u  +  (2 * dg_ct  -  (dyn . y) * u^2 / s) * y
#
# ((dyn . y) is the row dot product; the u^2/s term is d(1/(s+eps))/dg
# = -u^2 / (2s) times dg/dy = 2y). That is exactly the
# ``dy_out + 2 * y * dg`` form the existing fused backward kernel
# rebuilds per tile, so the normed backward delegates to the SAME
# ``ff_dense_bwd`` Pallas kernel with folded cotangents
#
#     dy_out' = dyn * u        dg' = dg_ct - (dyn . y) * u^2 / (2s)
#
# — only O(M) / O(M*N) element-wise prep runs outside the kernel, never
# an extra matmul. Raw y is rebuilt from the residuals as yn * (s + eps)
# (same sign as y, so the kernel's ReLU mask is unchanged). All-ReLU-dead
# rows (g = 0) get an EXACT zero gradient here: dg' is 0/0 = NaN for
# them, but the bwd kernel multiplies it by y = 0 and then applies the
# y > 0 mask via jnp.where, which discards the NaN. jax.grad of the
# composed oracle instead propagates NaN on such rows (d sqrt(g) at
# g = 0 is inf) — the fused path is the well-defined one, and the two
# only differ on rows where the oracle has no usable gradient at all.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def ff_dense_norm_vjp(x, w, b, interpret=True, blocks=None):
    """Differentiable fused FF layer WITH the in-kernel norm epilogue.
    Returns (yn (M, N) length-normalized, RAW goodness (M,)).

    ``interpret`` and ``blocks`` must be passed positionally (custom_vjp
    nondiff args); use interpret=True everywhere except on a real TPU.
    ``blocks`` as in ``ff_dense_vjp`` — every candidate the autotuner
    offers here already passed the VMEM row-residency filter
    (``ff_dense.vmem_block_bytes``), since norm=True keeps the whole
    (bm, N) row block resident across the inner sweep.
    """
    fwd_kw, _ = _split_blocks(blocks)
    return _ff_dense_fwd(x, w, b, interpret=interpret, norm=True,
                         **fwd_kw)


def _ff_dense_norm_vjp_fwd(x, w, b, interpret, blocks):
    fwd_kw, _ = _split_blocks(blocks)
    yn, g = _ff_dense_fwd(x, w, b, interpret=interpret, norm=True,
                          **fwd_kw)
    return (yn, g), (x, w, b, yn, g)


def _ff_dense_norm_vjp_bwd(interpret, blocks, res, cts):
    x, w, b, yn, g = res
    dyn, dg_ct = cts
    s = jnp.sqrt(g)
    u = 1.0 / (s + NORM_EPS)
    scale = s + NORM_EPS
    y = yn * scale[:, None]
    rowdot = jnp.sum(dyn * yn, axis=-1) * scale      # = dyn . y
    dg_eff = dg_ct - rowdot * u * u / (2.0 * s)
    dy_out_eff = dyn * u[:, None]
    _, bwd_kw = _split_blocks(blocks)
    dx, dw, db = ff_dense_bwd(x, w, y, dy_out_eff, dg_eff,
                              interpret=interpret, **bwd_kw)
    return dx, dw, db.astype(b.dtype)


ff_dense_norm_vjp.defvjp(_ff_dense_norm_vjp_fwd, _ff_dense_norm_vjp_bwd)
