"""Quickstart: train the paper's FF MLP on the synthetic MNIST-like task
through the ``repro.api`` facade, evaluate the classifier that was
actually trained, then simulate the PFF schedules from the measured task
timings.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro import api, data
from repro.configs.ff_mlp import FFMLPConfig

# scaled-down paper config (paper: [784, 2000 x4], E=100, S=100)
task = data.mnist_like(n_train=2560, n_test=500)
cfg = FFMLPConfig(
    layer_sizes=(task.dim, 400, 400, 400),
    epochs=60, splits=6,
    neg_mode="random",          # any of api.negatives.names()
    classifier="goodness",      # any of api.classifier.names()
)

print("training FF (sequential chapter schedule via api.fit)...")
result = api.fit(cfg, task, probe_every=2, verbose=True)

# Evaluate ONLY classifiers that were actually trained: the softmax head
# is a separate chapter task that exists iff classifier="softmax" — an
# untrained head would report chance-level "accuracy".
from repro.core import ff_mlp

print(f"\n{cfg.classifier.capitalize()} prediction accuracy: "
      f"{result.test_acc:.4f}")
if cfg.classifier == "softmax":
    # goodness prediction needs no head — it is always available
    good_acc = ff_mlp.accuracy(result.params, task.x_test, task.y_test,
                               cfg.num_classes, mode="goodness")
    print(f"Goodness prediction accuracy: {good_acc:.4f}")
else:
    print("Softmax head: not trained with classifier="
          f"{cfg.classifier!r} — rerun with classifier=\"softmax\" to "
          "compare both prediction modes.")

print("\nPFF schedules (simulated from measured task durations):")
for sched, n in (("sequential", 1), ("single_layer", 4),
                 ("all_layers", 4)):
    sim = api.simulate(result, sched, n)
    print(f"  {sched:13s} N={n}: {sim.makespan:7.1f}s "
          f"speedup x{sim.speedup:4.2f} utilization {sim.utilization:.2f}")
