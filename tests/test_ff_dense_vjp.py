"""Gradient correctness of the fused FF custom_vjp (deliverable of the
hot-loop PR): the Pallas backward kernel vs jax.grad through the jnp
oracle, and ref-vs-pallas weight-stream equality of the chapter trainer.
Also covers the in-kernel norm epilogue (``norm=True``): value and
gradient parity vs the composed oracle on non-tile-aligned shapes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.core import ff, ff_mlp
from repro.kernels import ref
from repro.kernels.ff_dense import NORM_EPS
from repro.kernels.ff_dense_vjp import ff_dense_norm_vjp, ff_dense_vjp


def _stacked_ff_loss(apply_fn):
    """Fused pos/neg FF loss over a stacked (2B, K) batch, built on
    either the custom_vjp kernel or the oracle."""
    def loss(lp, xb, theta, peer_w):
        y, g = apply_fn(xb, lp["w"], lp["b"])
        g = g / y.shape[-1]
        half = xb.shape[0] // 2
        out = ff.ff_loss(g[:half], g[half:], theta)
        return out + peer_w * ff.peer_norm_loss(y[:half])
    return loss


_FUSED = _stacked_ff_loss(lambda x, w, b: ff_dense_vjp(x, w, b, True))
_ORACLE = _stacked_ff_loss(ref.ff_dense_ref)


@pytest.mark.parametrize("M,K,N", [(100, 333, 257), (64, 784, 512),
                                   (100, 784, 2000), (16, 64, 64)])
@pytest.mark.parametrize("peer_w", [0.0, 0.3])
def test_fused_grad_matches_oracle(M, K, N, peer_w, key):
    """Non-tile-aligned shapes exercise the padded backward path; the
    peer term exercises the dy cotangent, the FF loss the dg one."""
    kx, kw = jax.random.split(jax.random.fold_in(key, M * N + K))
    x = jax.random.normal(kx, (M, K), jnp.float32)
    lp = {"w": jax.random.normal(kw, (K, N), jnp.float32) * K ** -0.5,
          "b": jnp.full((N,), 0.1, jnp.float32)}
    gf, gxf = jax.grad(_FUSED, argnums=(0, 1))(lp, x, 2.0, peer_w)
    gr, gxr = jax.grad(_ORACLE, argnums=(0, 1))(lp, x, 2.0, peer_w)
    np.testing.assert_allclose(gf["w"], gr["w"], rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(gf["b"], gr["b"], rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(gxf, gxr, rtol=1e-4, atol=1e-6)


def test_fused_value_matches_oracle(key):
    x = jax.random.normal(key, (100, 333), jnp.float32)
    w = jax.random.normal(key, (333, 257), jnp.float32) * 333 ** -0.5
    b = jnp.full((257,), 0.05, jnp.float32)
    for peer_w in (0.0, 0.3):
        lf = _FUSED({"w": w, "b": b}, x, 2.0, peer_w)
        lr = _ORACLE({"w": w, "b": b}, x, 2.0, peer_w)
        np.testing.assert_allclose(lf, lr, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# In-kernel norm epilogue (norm=True): the inter-layer divide fused into
# the Pallas kernel, vs the composed jnp oracle.
# ---------------------------------------------------------------------------

def _normed_loss(apply_fn):
    """A loss exercising BOTH outputs of the normed kernel: the
    normalized activation (dyn cotangent, through a §4.4-style head
    matmul) and the raw goodness (dg cotangent)."""
    def loss(lp, xb, v):
        yn, g = apply_fn(xb, lp["w"], lp["b"])
        return jnp.mean((yn @ v) ** 2) + jnp.mean(jnp.tanh(g))
    return loss


_NORM_FUSED = _normed_loss(lambda x, w, b: ff_dense_norm_vjp(x, w, b, True))
_NORM_ORACLE = _normed_loss(ref.ff_dense_norm_ref)


@pytest.mark.parametrize("M,K,N", [(100, 333, 257), (90, 784, 200),
                                   (16, 64, 64), (128, 100, 384)])
def test_norm_epilogue_value_matches_oracle(M, K, N, key):
    """Non-tile-aligned shapes exercise the padded row-resident block:
    the zero-padded N columns must not perturb the in-kernel
    normalizer."""
    kx, kw = jax.random.split(jax.random.fold_in(key, M + N))
    x = jax.random.normal(kx, (M, K), jnp.float32)
    w = jax.random.normal(kw, (K, N), jnp.float32) * K ** -0.5
    b = jnp.full((N,), 0.1, jnp.float32)
    yn, g = ff_dense_norm_vjp(x, w, b, True)
    yr, gr = ref.ff_dense_norm_ref(x, w, b)
    np.testing.assert_allclose(yn, yr, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(g, gr, rtol=1e-5, atol=1e-5)
    # the normalized rows must have (near-)unit length wherever any
    # unit fired — the epilogue divided by the right normalizer
    lengths = jnp.linalg.norm(yn, axis=-1)
    fired = g > 1e-6
    np.testing.assert_allclose(np.asarray(lengths)[np.asarray(fired)],
                               1.0, rtol=1e-4)


@pytest.mark.parametrize("M,K,N", [(100, 333, 257), (16, 64, 64)])
def test_norm_epilogue_grad_matches_oracle(M, K, N, key):
    """The folded-cotangent backward (norm chain rule delegated to the
    fused bwd kernel) vs jax.grad through the composed oracle."""
    kx, kw, kv = jax.random.split(jax.random.fold_in(key, M + N), 3)
    x = jax.random.normal(kx, (M, K), jnp.float32)
    lp = {"w": jax.random.normal(kw, (K, N), jnp.float32) * K ** -0.5,
          "b": jnp.full((N,), 0.1, jnp.float32)}
    v = jax.random.normal(kv, (N,), jnp.float32)
    gf, gxf = jax.grad(_NORM_FUSED, argnums=(0, 1))(lp, x, v)
    gr, gxr = jax.grad(_NORM_ORACLE, argnums=(0, 1))(lp, x, v)
    np.testing.assert_allclose(gf["w"], gr["w"], rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(gf["b"], gr["b"], rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(gxf, gxr, rtol=1e-4, atol=1e-6)


# ---------------------------------------------------------------------------
# Tuned block shapes through the custom_vjp: the autotuner hands
# (bm, bn, bk) tuples down both fused paths — gradients must match the
# oracle for ANY legal blocks, not just the defaults, on
# non-tile-aligned shapes (the padded row/column edge cases).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("blocks", [None, (32, 128, 64), (64, 256, 128),
                                    (16, 128, 256)])
def test_tuned_blocks_grad_matches_oracle(blocks, key):
    M, K, N = 100, 333, 257          # deliberately not tile-aligned
    fused = _stacked_ff_loss(
        lambda x, w, b: ff_dense_vjp(x, w, b, True, blocks))
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (M, K), jnp.float32)
    lp = {"w": jax.random.normal(kw, (K, N), jnp.float32) * K ** -0.5,
          "b": jnp.full((N,), 0.1, jnp.float32)}
    gf, gxf = jax.grad(fused, argnums=(0, 1))(lp, x, 2.0, 0.3)
    gr, gxr = jax.grad(_ORACLE, argnums=(0, 1))(lp, x, 2.0, 0.3)
    np.testing.assert_allclose(gf["w"], gr["w"], rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(gf["b"], gr["b"], rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(gxf, gxr, rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize("blocks", [None, (32, 128, 64), (16, 128, 256)])
def test_tuned_blocks_norm_grad_matches_oracle(blocks, key):
    """Same sweep through the norm-epilogue vjp — the whole-row
    residency path must stay grad-exact under tuned blocks too."""
    M, K, N = 90, 333, 257
    fused = _normed_loss(
        lambda x, w, b: ff_dense_norm_vjp(x, w, b, True, blocks))
    kx, kw, kv = jax.random.split(key, 3)
    x = jax.random.normal(kx, (M, K), jnp.float32)
    lp = {"w": jax.random.normal(kw, (K, N), jnp.float32) * K ** -0.5,
          "b": jnp.full((N,), 0.1, jnp.float32)}
    v = jax.random.normal(kv, (N,), jnp.float32)
    yn, g = ff_dense_norm_vjp(x, lp["w"], lp["b"], True, blocks)
    yr, gr_ = ref.ff_dense_norm_ref(x, lp["w"], lp["b"])
    np.testing.assert_allclose(yn, yr, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(g, gr_, rtol=1e-5, atol=1e-5)
    gf, gxf = jax.grad(fused, argnums=(0, 1))(lp, x, v)
    gr, gxr = jax.grad(_NORM_ORACLE, argnums=(0, 1))(lp, x, v)
    np.testing.assert_allclose(gf["w"], gr["w"], rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(gf["b"], gr["b"], rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(gxf, gxr, rtol=1e-4, atol=1e-6)


def test_fwd_norm_ref_is_bit_identical_to_composed_norm(key):
    """The ref path of the fused hand-off must reproduce the historical
    ``_norm(layer_apply(...))`` weight-stream bit-for-bit — that is what
    keeps every pre-existing sequential/executor oracle unchanged."""
    x = jax.random.normal(key, (100, 333), jnp.float32)
    lp = {"w": jax.random.normal(key, (333, 257), jnp.float32) * 0.05,
          "b": jnp.full((257,), 0.1, jnp.float32)}
    a = ff_mlp.fwd_norm(lp, x, impl="ref")
    old = ff_mlp._norm(ff_mlp.layer_apply(lp, x))
    assert bool(jnp.array_equal(a, old))


def test_norm_epilogue_dead_rows_no_nan():
    """An all-ReLU-dead row (g = 0) must normalize to zeros, not NaN —
    in the FORWARD and in the GRADIENT. The backward's dg' is 0/0 = NaN
    on such rows and is discarded only because the bwd kernel masks dy
    with jnp.where(y > 0, ..., 0); this pins that invariant (jax.grad
    of the composed oracle NaNs here — the fused path must not)."""
    x = jnp.zeros((4, 64), jnp.float32)
    w = jnp.zeros((64, 128), jnp.float32)
    b = jnp.full((128,), -1.0, jnp.float32)     # relu kills every unit
    yn, g = ff_dense_norm_vjp(x, w, b, True)
    assert bool(jnp.all(yn == 0.0)) and bool(jnp.all(g == 0.0))
    assert NORM_EPS > 0.0
    v = jnp.ones((128,), jnp.float32)
    gw, gx = jax.grad(_NORM_FUSED, argnums=(0, 1))(
        {"w": w, "b": b}, x, v)
    for leaf in (gw["w"], gw["b"], gx):
        assert bool(jnp.all(jnp.isfinite(leaf))), "NaN leaked through " \
            "the dead-row backward (dy must be masked via jnp.where)"


def _run_chapter(impl, key, K, N, n, batch, epochs):
    kx, kn, kw, kt = jax.random.split(key, 4)
    # fresh buffers per run: the chapter trainer donates lp/opt
    x_pos = jax.random.normal(kx, (n, K), jnp.float32)
    x_neg = jax.random.normal(kn, (n, K), jnp.float32)
    lp = {"w": jax.random.normal(kw, (K, N), jnp.float32) * K ** -0.5,
          "b": jnp.zeros((N,), jnp.float32)}
    opt = optim.adam_init(lp)
    lrs = jnp.full((epochs,), 0.01, jnp.float32)
    stream = []
    for chapter in range(2):
        lp, opt = ff_mlp.train_layer_chapter(
            lp, opt, x_pos, x_neg, lrs, jax.random.fold_in(kt, chapter),
            batch=batch, epochs=epochs, theta=2.0, peer_w=0.0, impl=impl)
        stream.append(jax.tree.map(np.asarray, lp))
    return stream


def test_train_layer_chapter_ref_vs_pallas_weight_stream(key):
    """kernel_impl=ref and kernel_impl=pallas (interpret) must produce
    the same weight stream to <= 1e-4 max-abs across chapters."""
    K, N = 333, 257          # deliberately not tile-aligned
    ref_stream = _run_chapter("ref", key, K, N, n=256, batch=64, epochs=2)
    pal_stream = _run_chapter("pallas", key, K, N, n=256, batch=64,
                              epochs=2)
    for lr_, lp_ in zip(ref_stream, pal_stream):
        for name in ("w", "b"):
            max_err = float(np.abs(lr_[name] - lp_[name]).max())
            assert max_err <= 1e-4, (name, max_err)


def _run_perf_opt_chapter(impl, key, K, N, n, batch, epochs):
    kx, kw, kh, kt = jax.random.split(key, 4)
    x = jax.random.normal(kx, (n, K), jnp.float32)
    y = jax.random.randint(kt, (n,), 0, 10)
    # fresh buffers per run: the trainer donates everything
    lp = {"w": jax.random.normal(kw, (K, N), jnp.float32) * K ** -0.5,
          "b": jnp.zeros((N,), jnp.float32)}
    head = {"w": jax.random.normal(kh, (N, 10), jnp.float32) * N ** -0.5,
            "b": jnp.zeros((10,), jnp.float32)}
    opt, opt_h = optim.adam_init(lp), optim.adam_init(head)
    lrs = jnp.full((epochs,), 0.01, jnp.float32)
    stream = []
    for chapter in range(2):
        lp, head, opt, opt_h = ff_mlp.train_layer_chapter_perf_opt(
            lp, head, opt, opt_h, x, y, lrs,
            jax.random.fold_in(kt, chapter), batch=batch, epochs=epochs,
            impl=impl)
        stream.append(jax.tree.map(np.asarray, (lp, head)))
    return stream


def test_perf_opt_chapter_ref_vs_pallas_weight_stream(key):
    """The §4.4 trainer drives the normed custom_vjp inside its hot
    loop — its ref and pallas weight streams must agree on a
    non-tile-aligned layer."""
    ref_stream = _run_perf_opt_chapter("ref", key, 333, 257, n=256,
                                       batch=64, epochs=2)
    pal_stream = _run_perf_opt_chapter("pallas", key, 333, 257, n=256,
                                       batch=64, epochs=2)
    for (lr_, hr_), (lp_, hp_) in zip(ref_stream, pal_stream):
        for a, b in ((lr_, lp_), (hr_, hp_)):
            for name in ("w", "b"):
                max_err = float(np.abs(a[name] - b[name]).max())
                assert max_err <= 1e-4, (name, max_err)
