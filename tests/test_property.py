"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="dev-only dependency (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro import optim
from repro.core import ff

SETTINGS = dict(max_examples=25, deadline=None)


@given(seed=st.integers(0, 2**31 - 1),
       vocab=st.integers(10, 50000),
       b=st.integers(1, 8), s=st.integers(8, 128))
@settings(**SETTINGS)
def test_corrupt_tokens_always_valid(seed, vocab, b, s):
    key = jax.random.PRNGKey(seed)
    tokens = jax.random.randint(key, (b, s), 0, vocab)
    neg = ff.corrupt_tokens(key, tokens, vocab)
    assert neg.shape == tokens.shape
    assert bool(jnp.all((neg >= 0) & (neg < vocab)))


@given(seed=st.integers(0, 2**31 - 1), c=st.integers(2, 20),
       n=st.integers(1, 64))
@settings(**SETTINGS)
def test_wrong_labels_never_true(seed, c, n):
    key = jax.random.PRNGKey(seed)
    y = jax.random.randint(key, (n,), 0, c)
    wrong = ff.random_wrong_labels(key, y, c)
    assert not bool(jnp.any(wrong == y))
    assert bool(jnp.all((wrong >= 0) & (wrong < c)))


@given(gp=st.floats(-10, 10), gn=st.floats(-10, 10),
       theta=st.floats(0.1, 5))
@settings(**SETTINGS)
def test_ff_loss_monotone(gp, gn, theta):
    """Loss strictly decreases in g_pos and increases in g_neg."""
    eps = 0.1
    l0 = float(ff.ff_loss(jnp.float32(gp), jnp.float32(gn), theta))
    l_pos = float(ff.ff_loss(jnp.float32(gp + eps), jnp.float32(gn), theta))
    l_neg = float(ff.ff_loss(jnp.float32(gp), jnp.float32(gn + eps), theta))
    assert l_pos < l0 + 1e-9
    assert l_neg > l0 - 1e-9


@given(seed=st.integers(0, 2**31 - 1), lr=st.floats(1e-5, 1e-1))
@settings(**SETTINGS)
def test_adam_descends_quadratic(seed, lr):
    """Adam on f(x) = |x|^2 must reduce the loss."""
    key = jax.random.PRNGKey(seed)
    x = {"w": jax.random.normal(key, (8,)) * 3}
    state = optim.adam_init(x)
    f = lambda p: jnp.sum(p["w"] ** 2)
    for step in range(1, 30):
        g = jax.grad(f)(x)
        x, state = optim.adam_update(x, g, state, lr=lr, step=step)
    assert float(f(x)) < float(jnp.sum((jax.random.normal(key, (8,)) * 3)
                                       ** 2))


@given(e=st.integers(1, 200), total=st.integers(10, 400))
@settings(**SETTINGS)
def test_cooldown_lr_bounds(e, total):
    lr = float(optim.cooldown_lr(0.01, min(e, total), total, 0.5))
    assert 0.0 <= lr <= 0.01 + 1e-12
    # before the midpoint the LR is exactly base
    if e <= total // 2 - 1:
        assert abs(lr - 0.01) < 1e-9


@given(seed=st.integers(0, 2**31 - 1),
       b=st.integers(1, 3),
       nc=st.integers(1, 4),
       h=st.sampled_from([1, 2, 4]),
       n=st.sampled_from([4, 16]))
@settings(max_examples=10, deadline=None)
def test_ssd_streaming_equals_sequential(seed, b, nc, h, n):
    """The chunked SSD scan == exact token recurrence for random sizes."""
    from repro.kernels import ref
    from repro.models.ssm import ssd_chunked
    key = jax.random.PRNGKey(seed)
    L = 16
    S = nc * L
    hd = 8
    ks = jax.random.split(key, 5)
    xh = jax.random.normal(ks[0], (b, S, h, hd), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bb = jax.random.normal(ks[3], (b, S, n), jnp.float32)
    cc = jax.random.normal(ks[4], (b, S, n), jnp.float32)
    y, hT = ssd_chunked(xh, dt, A, bb, cc, L)
    yr, hTr = ref.mamba2_ssd_ref(xh * dt[..., None], dt * A, bb, cc)
    np.testing.assert_allclose(y, yr, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(hT, hTr, rtol=3e-4, atol=3e-4)


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_checkpoint_roundtrip(seed, tmp_path_factory):
    from repro import checkpoint
    key = jax.random.PRNGKey(seed)
    tree = {"a": jax.random.normal(key, (4, 3)),
            "b": ({"c": jnp.arange(5)},
                  jax.random.normal(key, (2,), jnp.bfloat16))}
    path = str(tmp_path_factory.mktemp("ckpt") / f"t{seed}.npz")
    checkpoint.save(path, tree, step=7)
    restored, step = checkpoint.restore(path, tree)
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(
            np.asarray(jnp.asarray(a, jnp.float32)),
            np.asarray(jnp.asarray(b, jnp.float32)))
