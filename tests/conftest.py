"""Shared fixtures. NOTE: no XLA_FLAGS here on purpose — tests must see
the real (single) CPU device; only launch/dryrun.py fakes 512 devices."""
import jax
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(autouse=True)
def _isolated_tune_table(tmp_path, monkeypatch):
    """Point the kernel tuning table at a per-test empty path, so a
    developer's populated ~/.cache table cannot steer impl="auto" and
    change what the suite measures. Tests that want a table tune into
    this path (or set their own REPRO_TUNE_TABLE)."""
    from repro.kernels import autotune

    monkeypatch.setenv("REPRO_TUNE_TABLE",
                       str(tmp_path / "tune_table.json"))
    autotune.invalidate_cache()
    yield
    autotune.invalidate_cache()
