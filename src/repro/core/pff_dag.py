"""The PFF chapter-task DAG — the single source of truth for WHAT runs
and in WHICH ORDER, shared by the event-driven simulator
(``repro.core.pff.simulate_schedule``) and the real multi-device executor
(``repro.core.pff_exec``).

With splits, FF training is a DAG of chapter-tasks
T(k, c) = "train layer k for C mini-epochs in chapter c" with

    T(k, c)  <-  T(k-1, c)   (input: layer k-1's output after chapter c)
    T(k, c)  <-  T(k, c-1)   (weights: layer k's own previous chapter)

and NO backward edges — backpropagation would add them, and they are why
GPipe/PipeDream have bubbles that PFF does not. Head and negative-
regeneration tasks hang off the train chain:

    head(c)     <-  T(L-1, c), head(c-1)     (feats + its own weights)
    neg_gen(c)  <-  T(L-1, c)                (AdaptiveNEG scores need the
                                              full chapter-c model)

``strict_neg`` additionally gates T(0, c) on neg_gen(c-1): that is the
executor's bit-exact mode (chapter c trains on negatives regenerated
from the FULL chapter-(c-1) model, exactly like the sequential trainer).
The paper's All-Layers AdaptiveNEG instead uses negatives "at whatever
freshness is available" — the simulator models that relaxation by
leaving the edge out.

Node assignments (N nodes, L layers, S chapters):
  sequential    — one node runs everything.
  single_layer  — node k owns layer k; it re-runs the forward pass of
                  layers < k over the train set each chapter (the
                  paper's Algorithm 1 lines 3-5).
  all_layers    — node i executes whole chapters c = i (mod N)
                  (Algorithm 2); it computes its own forward features
                  while it trains, so no extra forward tasks appear.
  federated     — all_layers assignment + node-local data shards.
"""
from __future__ import annotations

import dataclasses
from typing import List

SCHEDULES = ("sequential", "single_layer", "all_layers", "federated")


@dataclasses.dataclass(frozen=True)
class Task:
    kind: str                  # train | head | neg_gen | local_head
    layer: int                 # -1 for non-layer tasks
    chapter: int


def node_of(schedule: str, num_nodes: int, *, layer: int,
            chapter: int) -> int:
    """Which node owns a train-task (schedule's static assignment)."""
    if schedule not in SCHEDULES:
        raise ValueError(f"unknown schedule {schedule!r}; "
                         f"expected one of {SCHEDULES}")
    if schedule == "sequential" or num_nodes == 1:
        return 0
    if schedule == "single_layer":
        return layer % num_nodes
    # all_layers / federated: node per chapter
    return chapter % num_nodes


def head_node_of(schedule: str, num_nodes: int, *, n_layers: int,
                 chapter: int) -> int:
    """The head trains where the chapter's last layer trained."""
    return node_of(schedule, num_nodes, layer=n_layers - 1,
                   chapter=chapter)


def neg_node_of(schedule: str, num_nodes: int, *, chapter: int) -> int:
    """Negative regeneration: in Single-Layer the LAST node generates
    and publishes for everyone (it is the only one holding the full
    model — the paper's observed serialization); in All-Layers/Federated
    the node that ran the chapter regenerates its own (parallel)."""
    if schedule == "single_layer" and num_nodes > 1:
        return num_nodes - 1
    return node_of(schedule, num_nodes, layer=0, chapter=chapter)


def chapter_train_nodes(schedule: str, num_nodes: int, n_layers: int, *,
                        chapter: int) -> List[int]:
    """All nodes that run train tasks in ``chapter`` — the consumers of
    anything published FOR that chapter (e.g. regenerated negatives)."""
    if schedule == "single_layer" and num_nodes > 1:
        return sorted({k % num_nodes for k in range(n_layers)})
    return [node_of(schedule, num_nodes, layer=0, chapter=chapter)]


def handoff_targets(schedule: str, num_nodes: int, *, n_layers: int,
                    splits: int, layer: int, chapter: int,
                    has_head: bool = False, has_neg: bool = False):
    """Cross-node consumers of train(layer, chapter)'s fresh weights —
    what the executor's double-buffered hand-off prefetches while the
    producing node is still busy. Derived from the same ``deps()`` edges
    and node assignments the dispatch order walks, so a prefetched copy
    can never be consumed at the wrong version.

    Returns ``(next_train_node, param_consumer_nodes)``:

    * ``next_train_node`` — the node that trains this layer in chapter
      + 1 and therefore needs the FULL (params, opt, ...) state; None
      when that is the producing node itself (single_layer: layer k
      lives on node k every chapter) or when this is the last chapter.
    * ``param_consumer_nodes`` — nodes that need only the layer PARAMS
      within this same chapter: the Algorithm-1 forward recompute of
      later layers, the softmax-head node and the negative-regeneration
      node (Single-Layer only — in All-Layers/Federated every
      within-chapter consumer runs on the chapter's own node).
    """
    src = node_of(schedule, num_nodes, layer=layer, chapter=chapter)
    nxt = None
    if chapter + 1 < splits:
        n = node_of(schedule, num_nodes, layer=layer, chapter=chapter + 1)
        if n != src:
            nxt = n
    params = set()
    if schedule == "single_layer" and num_nodes > 1:
        for k in range(layer + 1, n_layers):
            params.add(node_of(schedule, num_nodes, layer=k,
                               chapter=chapter))
        if has_head:
            params.add(head_node_of(schedule, num_nodes,
                                    n_layers=n_layers, chapter=chapter))
        if has_neg:
            params.add(neg_node_of(schedule, num_nodes, chapter=chapter))
    params.discard(src)
    return nxt, sorted(params)


def build_tasks(n_layers: int, splits: int, *, has_head: bool = False,
                has_neg: bool = False,
                has_local_heads: bool = False) -> List[Task]:
    """All tasks in canonical (sequential-trainer) order — a valid
    topological order of ``deps``, which is what both the simulator's
    event loop and the executor's dispatch loop walk.

    has_local_heads: the Performance-Optimized goodness path (paper
    §4.4) — each layer's local softmax head is a per-layer dependent of
    that layer's train task, owned by the same node. The executor fuses
    each local_head into its train task (they share one two-layer-deep
    backprop call — that is the §4.4 objective), which preserves this
    order exactly."""
    tasks: List[Task] = []
    for c in range(splits):
        for k in range(n_layers):
            tasks.append(Task("train", k, c))
            if has_local_heads:
                tasks.append(Task("local_head", k, c))
        if has_head:
            tasks.append(Task("head", n_layers, c))
        if has_neg:
            tasks.append(Task("neg_gen", -1, c))
    return tasks


def replay_frontier(n_layers: int, splits: int, start_chapter: int, *,
                    has_head: bool = False, has_neg: bool = False,
                    strict_neg: bool = False,
                    has_local_heads: bool = False,
                    head_feedback: bool = False) -> List[Task]:
    """The tasks a resumed executor must (re)execute when every chapter
    < ``start_chapter`` has completed — i.e. the DAG restricted to
    chapters >= ``start_chapter``, in canonical order.

    FF's core property makes this cut well-defined: every dependency
    edge points backward by at most one chapter (there are NO backward
    edges at all — the reason a chapter checkpoint is a consistent
    recovery line, unlike a mid-step backprop snapshot). This helper
    VERIFIES that closure — every dep of a frontier task either belongs
    to a completed chapter or precedes it inside the frontier — so a
    resume from a bad chapter index fails loudly instead of replaying
    an inconsistent stream.
    """
    if not 0 <= start_chapter <= splits:
        raise ValueError(f"start_chapter {start_chapter} outside "
                         f"[0, {splits}]")
    frontier = [t for t in build_tasks(n_layers, splits,
                                       has_head=has_head, has_neg=has_neg,
                                       has_local_heads=has_local_heads)
                if t.chapter >= start_chapter]
    seen: set = set()
    for t in frontier:
        for d in deps(t, n_layers, has_head=has_head, has_neg=has_neg,
                      strict_neg=strict_neg,
                      has_local_heads=has_local_heads,
                      head_feedback=head_feedback):
            if d.chapter >= start_chapter and d not in seen:
                raise ValueError(
                    f"chapter {start_chapter} is not a valid replay "
                    f"frontier: {t} depends on unexecuted {d}")
        seen.add(t)
    return frontier


def deps(task: Task, n_layers: int, *, has_head: bool = False,
         has_neg: bool = False, strict_neg: bool = False,
         has_local_heads: bool = False,
         head_feedback: bool = False) -> List[Task]:
    """Direct dependencies of ``task`` (see module docstring).

    head_feedback: LM chapters with tied embeddings — the head task
    updates the shared embed table, and every chapter-c train task
    embeds its tokens with the post-head-(c-1) table. The edge is
    recorded at layer 0 only; layers > 0 inherit it through their
    train(k-1, c) chain, so the closure is unchanged."""
    k, c = task.layer, task.chapter
    out: List[Task] = []
    if task.kind == "train":
        if k > 0:
            out.append(Task("train", k - 1, c))
        if c > 0:
            out.append(Task("train", k, c - 1))
            if has_local_heads:
                # §4.4: the chapter-c train task backprops THROUGH the
                # layer's local head, so it consumes the head weights
                # produced by chapter-(c-1)'s local_head task
                out.append(Task("local_head", k, c - 1))
            if has_head and head_feedback and k == 0:
                out.append(Task("head", n_layers, c - 1))
        if k == 0 and c > 0 and has_neg and strict_neg:
            out.append(Task("neg_gen", -1, c - 1))
    elif task.kind == "local_head":
        out.append(Task("train", k, c))
        if c > 0:
            out.append(Task("local_head", k, c - 1))
    elif task.kind == "head":
        out.append(Task("train", n_layers - 1, c))
        if c > 0:
            out.append(Task("head", n_layers, c - 1))
    elif task.kind == "neg_gen":
        out.append(Task("train", n_layers - 1, c))
    else:
        raise ValueError(f"unknown task kind {task.kind!r}")
    return out
