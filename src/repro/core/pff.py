"""Pipeline Forward-Forward (PFF): the paper's distributed schedules.

The key observation the paper exploits: with splits, FF training is a DAG
of chapter-tasks T(k, c) = "train layer k for C epochs in chapter c" with
forward-only dependencies and NO backward edges — that is what
backpropagation would add, and why GPipe/PipeDream have bubbles that PFF
does not. Because the DAG (not the node assignment) fixes the
weight-update order, Sequential, Single-Layer PFF and All-Layers PFF
produce IDENTICAL weight streams — they differ only in wall-clock.

The PFF machinery is split across three modules:

  * ``repro.core.pff_dag``  — the chapter-task DAG itself (task set,
    dependency edges, per-schedule node assignments). Single source of
    truth consumed by both the simulator and the executor.
  * this module — (a) the canonical sequential trainer
    (``run_chapter_schedule``; drive it via ``repro.api.fit``), which
    executes the chapter schedule once, timing
    every task, and (b) an event-driven simulator
    (``simulate_schedule``) that replays those timings under each
    schedule's node assignment to obtain distributed training time,
    utilization and bubble fraction — the paper's Tables 1-3.
  * ``repro.core.pff_exec`` — the REAL executor: runs the same DAG
    concurrently across an actual ``jax.devices()`` set (one device per
    paper "node") with async dispatch and ``device_put`` hand-off, and
    reproduces this module's weight stream bit-exactly for All-Layers.
    ``benchmarks/pff_exec.py`` records its measured makespan next to
    the simulator's prediction.

Federated PFF additionally changes the data each chapter sees
(node-local shards), so it is always trained for real with per-node data
(``run_federated_schedule`` here, or the executor with
schedule="federated"; both via ``repro.api.fit``).

AdaptiveNEG adds a per-chapter negative-regeneration task; in Single-Layer
the LAST node generates and publishes negatives (serializing), while in
All-Layers/Federated each node regenerates its own (parallel) — this
asymmetry reproduces the paper's observed Single-Layer penalty.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import data as data_lib, optim
from repro.core import ff, ff_mlp, pff_dag, strategies


# ---------------------------------------------------------------------------
# Canonical chapter-schedule trainer (times every task)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TaskRecord:
    kind: str                  # train | forward | neg_gen | head | publish
    layer: int                 # -1 for non-layer tasks
    chapter: int
    duration: float


@dataclasses.dataclass
class TrainResult:
    params: dict
    records: List[TaskRecord]
    test_acc: float
    train_acc: float
    cfg: object
    history: List[Tuple[int, float]]       # (chapter, test_acc) probes


def run_chapter_schedule(cfg, task: data_lib.ImageTask, *, probe_every=0,
                         node_data: Optional[List[np.ndarray]] = None,
                         num_nodes: int = 1, verbose=False) -> TrainResult:
    """Runs the canonical chapter schedule of the paper (the facade's
    ``sequential`` / ``federated`` backends — call ``repro.api.fit``).

    All strategy variation (negatives, goodness, classifier) comes from
    the ``repro.core.strategies`` registries; this driver only walks the
    chapter x layer task order and times every task.

    node_data: optional list of per-node index arrays (Federated PFF) —
    chapter c uses node (c % num_nodes)'s shard.
    """
    good = strategies.goodness.get(cfg.goodness_fn)
    neg = strategies.negatives.get(cfg.neg_mode)
    cls = strategies.classifier.get(cfg.classifier)
    key = jax.random.PRNGKey(cfg.seed)
    params = ff_mlp.init(key, cfg)
    opt = ff_mlp.opt_init(params)
    records: List[TaskRecord] = []
    history = []

    S = cfg.splits
    C = max(cfg.epochs // cfg.splits, 1)
    n_layers = len(params["layers"])
    x_all = jnp.asarray(task.x_train)
    y_all = jnp.asarray(task.y_train)
    impl = ff_mlp.kernel_impl(cfg)
    has_neg = good.uses_negatives and neg.regenerates

    # Hoisted out of the chapter loop: label overlays and the layer-0
    # length-normalization are chapter-invariant (the positive overlay
    # never changes; the negative one changes only on regeneration), so
    # recomputing them every chapter x layer would be pure waste.
    kneg = jax.random.fold_in(key, 999)
    if good.uses_negatives:
        # only the normalized forms are kept — the raw overlays would be
        # ~190 MB of dead weight each at MNIST scale. The initial
        # negatives pass params=None/scores=None: every strategy degrades
        # to key-only wrong labels before a model exists (the executor
        # does the same, so custom strategies see one uniform contract).
        xp0 = ff_mlp._norm(ff.overlay_label(x_all, y_all, cfg.num_classes))
        xn0 = ff_mlp._norm(neg.fn(kneg, cfg, None, x_all, y_all, None))
    if not good.uses_negatives or cls.trains_head:
        x_neutral = ff.overlay_neutral(x_all, cfg.num_classes)
        if not good.uses_negatives:
            xk0 = ff_mlp._norm(x_neutral)

    for chapter in range(S):
        if node_data is not None:
            idx = jnp.asarray(node_data[chapter % num_nodes])
        else:
            idx = None
        # learning-rate for this chapter's mini-epochs
        lrs = jnp.asarray([
            optim.cooldown_lr(cfg.lr_ff, chapter * C + e, cfg.epochs,
                              cfg.cooldown_after) for e in range(C)],
            jnp.float32)
        lrs_head = lrs * (cfg.lr_softmax / cfg.lr_ff)
        kc = jax.random.fold_in(key, chapter)

        # per-chapter inputs: activations flow layer-to-layer, extras
        # (labels) do not
        if good.uses_negatives:
            acts = (xp0 if idx is None else xp0[idx],
                    xn0 if idx is None else xn0[idx])
            extras = ()
        else:
            acts = (xk0 if idx is None else xk0[idx],)
            extras = (y_all if idx is None else y_all[idx],)

        for k in range(n_layers):
            t0 = time.perf_counter()
            state = good.train_chapter(
                good.get_state(params, opt, k), acts, extras, lrs,
                jax.random.fold_in(kc, k), cfg=cfg, epochs=C)
            jax.block_until_ready(state[0])
            good.set_state(params, opt, k, state)
            if k + 1 < n_layers:
                # propagate data through the freshly-trained layer
                acts = tuple(ff_mlp.fwd_norm(state[0], a, impl=impl)
                             for a in acts)
            records.append(TaskRecord(
                "train", k, chapter, time.perf_counter() - t0))

        # softmax head (trained alongside, layer-local — paper §3)
        if cls.trains_head:
            t0 = time.perf_counter()
            xn_all = x_neutral if idx is None else x_neutral[idx]
            feats = ff_mlp.softmax_feats(params["layers"], xn_all,
                                         impl=impl)
            params["head"], opt["head"] = ff_mlp.train_head_chapter(
                params["head"], opt["head"], feats,
                y_all if idx is None else y_all[idx],
                lrs_head, jax.random.fold_in(kc, 77),
                batch=cfg.batch_size, epochs=C)
            jax.block_until_ready(params["head"]["w"])
            records.append(TaskRecord(
                "head", n_layers, chapter, time.perf_counter() - t0))

        # negative regeneration (UpdateXNEG)
        if has_neg:
            t0 = time.perf_counter()
            # params travel with scores: only needs_scores strategies see
            # the live model (key-only regen gets None on the executor's
            # per-node path too — keep both drivers' contracts identical)
            scores = None
            if neg.needs_scores:
                scores = _class_scores_chunked(params, x_all, cfg)
            xn0 = ff_mlp._norm(neg.fn(
                jax.random.fold_in(kneg, chapter), cfg,
                params if neg.needs_scores else None,
                x_all, y_all, scores))
            jax.block_until_ready(xn0)
            records.append(TaskRecord(
                "neg_gen", -1, chapter, time.perf_counter() - t0))

        if probe_every and (chapter + 1) % probe_every == 0:
            acc = ff_mlp.accuracy(params, task.x_test, task.y_test,
                                  cfg.num_classes, good.eval_mode(cfg),
                                  impl=impl)
            history.append((chapter + 1, acc))
            if verbose:
                print(f"  chapter {chapter + 1}/{S}: test acc {acc:.4f}")

    mode = good.eval_mode(cfg)
    test_acc = ff_mlp.accuracy(params, task.x_test, task.y_test,
                               cfg.num_classes, mode, impl=impl)
    train_acc = ff_mlp.accuracy(params, task.x_train[:2000],
                                task.y_train[:2000], cfg.num_classes, mode,
                                impl=impl)
    return TrainResult(params, records, test_acc, train_acc, cfg, history)


def _class_scores_chunked(params, x, cfg, chunk=2000):
    """Full-train-set goodness scores for AdaptiveNEG regeneration —
    one shared chunked loop (``ff_mlp.chunked_scores``) with accuracy()
    and the facade's eval step."""
    impl = ff_mlp.kernel_impl(cfg)
    return ff_mlp.chunked_scores(
        lambda xc: ff_mlp.goodness_class_scores(params, xc,
                                                cfg.num_classes, impl=impl),
        x, chunk=chunk)


# ---------------------------------------------------------------------------
# Event-driven schedule simulator
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SimResult:
    schedule: str
    num_nodes: int
    makespan: float
    sequential_time: float
    speedup: float
    utilization: float
    bubble_fraction: float
    node_busy: List[float]


def task_durations(records: List[TaskRecord], *, reducer=np.median):
    """Duration per (kind, layer), reduced with ``reducer``.

    The default ``np.median`` is robust to jit-compile outliers (the
    first occurrence of every task shape pays compilation). The reducer
    is exposed because these durations are what ``simulate_schedule``
    replays — and what the real executor (``repro.core.pff_exec``) is
    validated against in ``benchmarks/pff_exec.py``.
    """
    acc: Dict[Tuple[str, int], List[float]] = {}
    for r in records:
        acc.setdefault((r.kind, r.layer), []).append(r.duration)
    return {k: float(reducer(v)) for k, v in acc.items()}


def simulate_schedule(records: List[TaskRecord], schedule: str,
                      num_nodes: int, *, comm_time: float = 0.0,
                      forward_frac: float = 0.18,
                      reducer=np.median) -> SimResult:
    """Replays the ``pff_dag`` task DAG under a node assignment.

    forward_frac: cost of re-running the forward pass of ONE layer over
    the train set, as a fraction of one train-task (used by Single-Layer,
    Algorithm 1 lines 3-5; measured ratio fwd/train ≈ C * this).

    Negatives are used at whatever freshness is available
    ("UpdateXNEG(publish=False)", regenerated per node): they do NOT
    gate the next chapter's start (``strict_neg=False`` in the DAG) —
    their cost appears only as node busy time. This matches the paper's
    All-Layers AdaptiveNEG behaviour; the executor's bit-exact mode
    gates instead.
    """
    dur = task_durations(records, reducer=reducer)
    layers = sorted({r.layer for r in records if r.kind == "train"})
    chapters = sorted({r.chapter for r in records if r.kind == "train"})
    L, S = len(layers), len(chapters)
    has_head = any(k == "head" for k, _ in dur)
    has_neg = any(k == "neg_gen" for k, _ in dur)
    has_local = any(k == "local_head" for k, _ in dur)

    t_train = {k: dur[("train", k)] for k in layers}
    t_head = dur.get(("head", L), 0.0)
    t_neg = dur.get(("neg_gen", -1), 0.0)
    t_local = {k: dur.get(("local_head", k), 0.0) for k in layers}
    # fair sequential baseline: same median task costs, one node
    seq_total = S * (sum(t_train.values()) + (t_head if has_head else 0.0)
                     + (t_neg if has_neg else 0.0)
                     + (sum(t_local.values()) if has_local else 0.0))

    def owner(task: pff_dag.Task) -> int:
        if task.kind == "head":
            return pff_dag.head_node_of(schedule, num_nodes, n_layers=L,
                                        chapter=task.chapter)
        if task.kind == "neg_gen":
            return pff_dag.neg_node_of(schedule, num_nodes,
                                       chapter=task.chapter)
        # train / local_head: a local head trains where its layer trains
        return pff_dag.node_of(schedule, num_nodes, layer=task.layer,
                               chapter=task.chapter)

    def cost(task: pff_dag.Task) -> float:
        if task.kind == "head":
            return t_head
        if task.kind == "neg_gen":
            return t_neg
        if task.kind == "local_head":
            return t_local[task.layer]
        extra = 0.0
        if schedule == "single_layer" and task.layer > 0:
            # re-forward layers < k over the train set (Algorithm 1)
            extra = forward_frac * sum(t_train[j]
                                       for j in range(task.layer))
        return extra + t_train[task.layer]

    # ---- event simulation over the shared DAG ------------------------------
    node_free = [0.0] * num_nodes
    node_busy = [0.0] * num_nodes
    done: Dict[pff_dag.Task, float] = {}

    for task in pff_dag.build_tasks(L, S, has_head=has_head,
                                    has_neg=has_neg,
                                    has_local_heads=has_local):
        n = owner(task)
        start = node_free[n]
        for dep in pff_dag.deps(task, L, has_head=has_head,
                                has_neg=has_neg,
                                has_local_heads=has_local):
            start = max(start, done[dep] +
                        (comm_time if owner(dep) != n else 0.0))
        t = cost(task)
        end = start + t
        node_free[n] = end
        node_busy[n] += t
        done[task] = end

    makespan = max(node_free)
    speedup = seq_total / makespan if makespan > 0 else 1.0
    util = sum(node_busy) / (num_nodes * makespan) if makespan else 1.0
    return SimResult(schedule, num_nodes, makespan, seq_total, speedup,
                     util, 1.0 - util, node_busy)


# ---------------------------------------------------------------------------
# Federated PFF (actually trains on node-local shards)
# ---------------------------------------------------------------------------

def federated_shards(cfg, task: data_lib.ImageTask, num_nodes: int):
    """The canonical federated shard split: a seed-deterministic
    permutation dealt round-robin, so every node (and the executor)
    reconstructs the same shards without communication."""
    rng = np.random.default_rng(cfg.seed)
    order = rng.permutation(len(task.x_train))
    return [order[i::num_nodes] for i in range(num_nodes)]


def run_federated_schedule(cfg, task: data_lib.ImageTask, num_nodes: int,
                           **kw) -> TrainResult:
    """Federated PFF's weight stream (the facade's ``federated`` backend)."""
    return run_chapter_schedule(cfg, task,
                                node_data=federated_shards(cfg, task,
                                                           num_nodes),
                                num_nodes=num_nodes, **kw)


# ---------------------------------------------------------------------------
# Elastic Federated PFF: membership-aware rounds + weighted aggregation
# ---------------------------------------------------------------------------

def weighted_average_trees(trees, weights):
    """Leaf-wise weighted average of pytrees — the federated round
    aggregator. ``weights`` are python floats (normalized live-shard
    fractions); accumulation walks ``trees`` in the given order, so two
    callers passing the same trees in the same order get BIT-IDENTICAL
    results (the elastic executor is checked against the sequential
    reference this way). Integer/bool leaves (none today, but e.g. step
    counters) must agree across trees and are taken from the first.
    """
    if len(trees) != len(weights) or not trees:
        raise ValueError(f"{len(trees)} trees vs {len(weights)} weights")

    def avg(*leaves):
        if not jnp.issubdtype(jnp.asarray(leaves[0]).dtype, jnp.floating):
            return leaves[0]
        acc = leaves[0] * weights[0]
        for leaf, w in zip(leaves[1:], weights[1:]):
            acc = acc + leaf * w
        return acc
    return jax.tree_util.tree_map(avg, *trees)


def elastic_node_round(good, cfg, states, head_state, acts, extras, lrs,
                       lrs_head, key_node, *, epochs, impl, y=None,
                       x_neutral=None, train_head=False):
    """One node's shard-local work for one elastic round, starting from
    (already copied/placed) round-start ``states``/``head_state``.

    This is THE round math — the sequential reference
    (``run_elastic_federated``) and the real executor's elastic driver
    both call exactly this function, which is what makes the
    multi-device aggregate bit-checkable against the single-device one.
    NOTE: the chapter trainers donate their inputs — callers pass
    per-node copies, never the round-start globals themselves.
    """
    out_states = []
    for k, st in enumerate(states):
        st = good.train_chapter(st, acts, extras, lrs,
                                jax.random.fold_in(key_node, k),
                                cfg=cfg, epochs=epochs)
        out_states.append(st)
        if k + 1 < len(states):
            acts = tuple(ff_mlp.fwd_norm(st[0], a, impl=impl)
                         for a in acts)
    if train_head:
        feats = ff_mlp.softmax_feats([s[0] for s in out_states],
                                     x_neutral, impl=impl)
        head, oph = ff_mlp.train_head_chapter(
            head_state[0], head_state[1], feats, y, lrs_head,
            jax.random.fold_in(key_node, 77), batch=cfg.batch_size,
            epochs=epochs)
        head_state = (head, oph)
    return out_states, head_state


def _check_membership(live, num_nodes, r):
    live = sorted(set(int(n) for n in live))
    if not live:
        raise ValueError(f"membership callback returned no live nodes "
                         f"for round {r}")
    bad = [n for n in live if not 0 <= n < num_nodes]
    if bad:
        raise ValueError(f"membership round {r}: node ids {bad} outside "
                         f"[0, {num_nodes})")
    return live


def run_elastic_federated(cfg, task: data_lib.ImageTask, num_nodes: int,
                          membership) -> TrainResult:
    """Sequential reference for ELASTIC Federated PFF (the executor's
    ``resilience.membership`` mode is bit-checked against this).

    Per round r (= one chapter's worth of work, cfg.splits rounds):
    ``membership(r)`` names the live nodes; each live node trains a COPY
    of the round-start model on ITS OWN shard for C mini-epochs
    (shard-local training — the property the paper's federated schedule
    already has), and the aggregator replaces the global model with the
    live results averaged by live shard sizes
    (``weighted_average_trees``). Nodes joining or leaving between
    rounds therefore change only which shards contribute and their
    weights — no global state is ever stranded on an absent node.

    Key-only negative strategies only: score-needing (AdaptiveNEG)
    regeneration reads the full global model, which does not exist
    mid-round on any single node.
    """
    good = strategies.goodness.get(cfg.goodness_fn)
    neg = strategies.negatives.get(cfg.neg_mode)
    cls = strategies.classifier.get(cfg.classifier)
    if good.uses_negatives and neg.regenerates and neg.needs_scores:
        raise ValueError(
            f"elastic federated membership supports key-only negative "
            f"strategies; {cfg.neg_mode!r} needs full-model scores")
    key = jax.random.PRNGKey(cfg.seed)
    kneg = jax.random.fold_in(key, 999)
    params = ff_mlp.init(key, cfg)
    opt = ff_mlp.opt_init(params)
    S = cfg.splits
    C = max(cfg.epochs // cfg.splits, 1)
    n_layers = len(params["layers"])
    impl = ff_mlp.kernel_impl(cfg)
    x_all = jnp.asarray(task.x_train)
    y_all = jnp.asarray(task.y_train)
    shards = [jnp.asarray(s)
              for s in federated_shards(cfg, task, num_nodes)]
    train_head = cls.trains_head

    if good.uses_negatives:
        xp0 = ff_mlp._norm(ff.overlay_label(x_all, y_all, cfg.num_classes))
        xn0 = ff_mlp._norm(neg.fn(kneg, cfg, None, x_all, y_all, None))
    else:
        xk0 = ff_mlp._norm(ff.overlay_neutral(x_all, cfg.num_classes))
    if train_head or not good.uses_negatives:
        x_neutral = ff.overlay_neutral(x_all, cfg.num_classes)

    states = [good.get_state(params, opt, k) for k in range(n_layers)]
    head_state = (params["head"], opt["head"])
    history = []
    for r in range(S):
        live = _check_membership(membership(r), num_nodes, r)
        history.append((r, len(live)))
        lrs = jnp.asarray([
            optim.cooldown_lr(cfg.lr_ff, r * C + e, cfg.epochs,
                              cfg.cooldown_after) for e in range(C)],
            jnp.float32)
        lrs_head = lrs * (cfg.lr_softmax / cfg.lr_ff)
        kr = jax.random.fold_in(key, r)
        if good.uses_negatives and neg.regenerates and r > 0:
            xn0 = ff_mlp._norm(neg.fn(jax.random.fold_in(kneg, r - 1),
                                      cfg, None, x_all, y_all, None))
        per_node = {}
        for node in live:
            idx = shards[node]
            if good.uses_negatives:
                acts, extras = (xp0[idx], xn0[idx]), ()
            else:
                acts, extras = (xk0[idx],), (y_all[idx],)
            placed = [jax.tree_util.tree_map(jnp.copy, st)
                      for st in states]
            placed_head = jax.tree_util.tree_map(jnp.copy, head_state)
            per_node[node] = elastic_node_round(
                good, cfg, placed, placed_head, acts, extras, lrs,
                lrs_head, jax.random.fold_in(kr, node), epochs=C,
                impl=impl, y=y_all[idx] if train_head else None,
                x_neutral=x_neutral[idx] if train_head else None,
                train_head=train_head)
        total = float(sum(len(shards[n]) for n in live))
        w = [len(shards[n]) / total for n in live]
        states = [weighted_average_trees(
            [per_node[n][0][k] for n in live], w)
            for k in range(n_layers)]
        if train_head:
            head_state = weighted_average_trees(
                [per_node[n][1] for n in live], w)

    final = {**good.export(states), "head": head_state[0]}
    mode = good.eval_mode(cfg)
    test_acc = ff_mlp.accuracy(final, task.x_test, task.y_test,
                               cfg.num_classes, mode, impl=impl)
    train_acc = ff_mlp.accuracy(final, task.x_train[:2000],
                                task.y_train[:2000], cfg.num_classes,
                                mode, impl=impl)
    return TrainResult(final, [], test_acc, train_acc, cfg, history)


# ---------------------------------------------------------------------------
# Deprecated entry points — the supported surface is ``repro.api.fit``
# ---------------------------------------------------------------------------

def train_ff_mlp(cfg, task: data_lib.ImageTask, *, probe_every=0,
                 node_data: Optional[List[np.ndarray]] = None,
                 num_nodes: int = 1, verbose=False) -> TrainResult:
    """Deprecated: use ``repro.api.fit(cfg, task, backend="sequential")``."""
    warnings.warn("pff.train_ff_mlp is deprecated; use repro.api.fit("
                  "cfg, task, backend=\"sequential\")",
                  DeprecationWarning, stacklevel=2)
    from repro import api
    if node_data is not None:       # pre-facade federated spelling
        return run_chapter_schedule(cfg, task, probe_every=probe_every,
                                    node_data=node_data,
                                    num_nodes=num_nodes, verbose=verbose)
    return api.fit(cfg, task, backend="sequential", probe_every=probe_every,
                   verbose=verbose).raw


def train_federated(cfg, task: data_lib.ImageTask, num_nodes: int,
                    **kw) -> TrainResult:
    """Deprecated: use ``repro.api.fit(cfg, task, backend="federated")``."""
    warnings.warn("pff.train_federated is deprecated; use repro.api.fit("
                  "cfg, task, backend=\"federated\", num_nodes=N)",
                  DeprecationWarning, stacklevel=2)
    from repro import api
    return api.fit(cfg, task, backend="federated", num_nodes=num_nodes,
                   **kw).raw
