"""Trace exporters: registry + Chrome/Perfetto and JSONL builtins.

Mirrors the registry-first style of ``core/strategies.py`` /
``kernels/registry.py``: exporters are looked up by name so the launch
CLIs can source ``--trace-format`` choices live from the registry and
downstream code can plug in new sinks without touching this module:

    from repro.obs import export as obs_export
    obs_export.register_exporter("my_sink", my_fn)   # fn(trace_dict, path)
    obs_export.export(tracer, "out.bin", format="my_sink")

Builtins:

* ``chrome`` — Chrome Trace Event JSON (the ``trace.json`` format
  Perfetto / ``chrome://tracing`` load directly): one phase-``X``
  complete event per span (``ts``/``dur`` in microseconds), one
  phase-``i`` instant event per tracer event, ``pid`` = the span's
  ``node`` attr (the paper's "node" — one pid lane per device) and
  ``tid`` = recording thread, with phase-``M`` metadata records naming
  both. Counters and tracer meta ride in ``otherData``.
* ``jsonl`` — one JSON object per line (header meta, then every span
  and event in recorded order, then a trailing counters record); the
  round-trippable form ``load_jsonl`` reads back for offline analysis.

Exporters receive the plain-data ``trace.to_dict()`` form, so anything
that quacks like it (e.g. ``load_jsonl``'s return value) re-exports.
"""
from __future__ import annotations

import json
from typing import Any, Dict

from repro.core.strategies import Registry

EXPORTERS = Registry("trace exporter")


def register_exporter(name: str, fn, *, overwrite: bool = False):
    """Register ``fn(trace_dict, path)`` under ``name``."""
    return EXPORTERS.register(name, fn, overwrite=overwrite)


def names():
    return EXPORTERS.names()


def _as_dict(trace) -> Dict[str, Any]:
    if isinstance(trace, dict):
        return trace
    return trace.to_dict()


def export(trace, path: str, format: str = "chrome") -> str:
    """Write ``trace`` (a Tracer or a trace dict) to ``path``."""
    EXPORTERS.get(format)(_as_dict(trace), path)
    return path


# ---------------------------------------------------------------------------
# chrome: Chrome Trace Event format (Perfetto-loadable trace.json)
# ---------------------------------------------------------------------------

def _span_pid(span: Dict[str, Any]) -> int:
    node = span.get("attrs", {}).get("node", None)
    return int(node) if isinstance(node, (int, float)) and node >= 0 else 0


def export_chrome(trace: Dict[str, Any], path: str) -> None:
    events = []
    tids: Dict[str, int] = {}
    pids = set()

    def tid_of(thread: str) -> int:
        if thread not in tids:
            tids[thread] = len(tids) + 1
        return tids[thread]

    for s in trace.get("spans", ()):
        pid = _span_pid(s)
        pids.add(pid)
        events.append({
            "name": s["name"], "ph": "X", "pid": pid,
            "tid": tid_of(s.get("thread", "main")),
            "ts": round(s["t0"] * 1e6, 3),
            "dur": round((s["t1"] - s["t0"]) * 1e6, 3),
            "args": s.get("attrs", {}),
        })
    for e in trace.get("events", ()):
        pid = _span_pid(e)
        pids.add(pid)
        events.append({
            "name": e["name"], "ph": "i", "s": "t", "pid": pid,
            "tid": tid_of(e.get("thread", "main")),
            "ts": round(e["t"] * 1e6, 3),
            "args": e.get("attrs", {}),
        })
    for pid in sorted(pids):
        events.append({"name": "process_name", "ph": "M", "pid": pid,
                       "tid": 0, "args": {"name": f"node {pid}"}})
    for thread, tid in tids.items():
        for pid in sorted(pids):
            events.append({"name": "thread_name", "ph": "M", "pid": pid,
                           "tid": tid, "args": {"name": thread}})
    doc = {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {"meta": trace.get("meta", {}),
                      "counters": trace.get("counters", {})},
    }
    with open(path, "w") as f:
        json.dump(doc, f)


# ---------------------------------------------------------------------------
# jsonl: line-per-record span log (round-trippable via load_jsonl)
# ---------------------------------------------------------------------------

def export_jsonl(trace: Dict[str, Any], path: str) -> None:
    with open(path, "w") as f:
        f.write(json.dumps({"kind": "meta",
                            "meta": trace.get("meta", {})}) + "\n")
        for s in trace.get("spans", ()):
            f.write(json.dumps({"kind": "span", **s}) + "\n")
        for e in trace.get("events", ()):
            f.write(json.dumps({"kind": "event", **e}) + "\n")
        f.write(json.dumps({"kind": "counters",
                            "counters": trace.get("counters", {})}) + "\n")


def load_jsonl(path: str) -> Dict[str, Any]:
    """Read an ``export_jsonl`` file back into the trace-dict form
    (``{"meta", "spans", "events", "counters"}``) that exporters and
    ``obs.analyze`` consume."""
    out: Dict[str, Any] = {"meta": {}, "spans": [], "events": [],
                           "counters": {}}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            kind = rec.pop("kind", None)
            if kind == "meta":
                out["meta"] = rec.get("meta", {})
            elif kind == "span":
                out["spans"].append(rec)
            elif kind == "event":
                out["events"].append(rec)
            elif kind == "counters":
                out["counters"] = rec.get("counters", {})
    return out


register_exporter("chrome", export_chrome)
register_exporter("jsonl", export_jsonl)
