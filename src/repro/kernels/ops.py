"""Dispatch layer: TPU -> Pallas kernel, anything else -> jnp oracle.

Model code imports from here; tests cross-validate both paths. On this
CPU container the Pallas path runs in interpret mode; on a real TPU it
compiles to Mosaic. ``ff_dense`` is fully differentiable on both paths
(the Pallas path carries a fused custom_vjp backward kernel) and is the
engine of the FF-MLP training hot loop — select the path with
``impl="auto" | "pallas" | "ref"`` (``FFMLPConfig.kernel_impl``).
"""
from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.ff_dense_vjp import (
    ff_dense_norm_vjp as _ff_dense_norm_vjp,
    ff_dense_vjp as _ff_dense_vjp,
)
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.mamba2_ssd import mamba2_ssd as _ssd_pallas


def _on_tpu():
    return jax.default_backend() == "tpu"


# the valid ``impl`` values for ff_dense — CLI --kernel-impl choices
# come from here so help text tracks the dispatcher
FF_DENSE_IMPLS = ("auto", "pallas", "ref")


def ff_dense(x, w, b, *, impl="auto", norm=False, force_pallas=False):
    """Fused (or reference) y = relu(x @ w + b), g = sum(y^2, -1).

    impl: "auto" picks Pallas on TPU and the jnp oracle elsewhere;
    "pallas" forces the fused kernel (interpret mode off-TPU); "ref"
    forces the oracle. ``force_pallas=True`` is the legacy spelling of
    impl="pallas". Differentiable under jax.grad on every path.

    norm=True: y is returned length-normalized (Hinton's inter-layer
    hand-off) — on the Pallas path the divide runs in the kernel
    epilogue, on the ref path in the jnp oracle; g stays the RAW
    pre-norm goodness on both.
    """
    if force_pallas:
        impl = "pallas"
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "pallas":
        fused = _ff_dense_norm_vjp if norm else _ff_dense_vjp
        return fused(x, w, b, not _on_tpu())
    if impl != "ref":
        raise ValueError(f"unknown ff_dense impl {impl!r}; expected one "
                         f"of {' | '.join(FF_DENSE_IMPLS)}")
    if norm:
        return ref.ff_dense_norm_ref(x, w, b)
    return ref.ff_dense_ref(x, w, b)


def flash_attention(q, k, v, *, causal=True, window=None,
                    force_pallas=False):
    if _on_tpu() or force_pallas:
        return _flash_pallas(q, k, v, causal=causal, window=window,
                             interpret=not _on_tpu())
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window)


def mamba2_ssd(xbar, dA, b, c, *, chunk=128, force_pallas=False):
    if _on_tpu() or force_pallas:
        return _ssd_pallas(xbar, dA, b, c, chunk=chunk,
                           interpret=not _on_tpu())
    return ref.mamba2_ssd_ref(xbar, dA, b, c)
