"""Ring attention: context parallelism over a mesh axis (beyond-paper).

Motivation (from the roofline table): prefill_32k for small dense archs
(qwen2-0.5b: batch 32, 14 heads) cannot fill a 256-chip pod with batch
and head parallelism alone — batch x heads < chips — so attention work
replicates. Sharding the SEQUENCE dimension is the missing axis.

Scheme (Liu et al. ring attention, TPU-adapted):
  * q, k, v sharded on the sequence dim over the `axis` (each device
    owns a contiguous S/P-token segment; segment order = device order).
  * P steps: each device holds its q segment, and the k/v segments
    ROTATE around the ring via collective_permute. Online softmax merges
    each incoming block, exactly like the flash kernel's inner loop but
    at inter-chip granularity.
  * causal masking is by global position, computed from the step index;
    fully-masked incoming blocks still rotate (the ring must stay in
    lockstep) but skip their matmuls' contribution via masking.

Communication: each step moves the local K/V (2 * S/P * kv_heads * hd
bytes) to the next neighbor — total = 2 * S * kv * hd per device per
layer, independent of P; compare an all-gather of K/V which needs the
same bytes but peaks memory at full-S K/V per device. Ring keeps peak
at 2 segments.

Used via ``ring_attention(q, k, v, axis="model", mesh=...)`` inside
shard_map (see ops in repro/core/train.py is NOT wired by default —
this is an opt-in building block exercised by tests and the context-
parallel §Perf experiment).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.sharding import shard_map as _shard_map


def _axis_size(axis):
    """Static mesh-axis size, usable for Python-level loop bounds.
    jax >= 0.5 has jax.lax.axis_size; 0.4.x exposes it via axis_frame."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis)
    frame = jax.core.axis_frame(axis)
    return getattr(frame, "size", frame)


NEG_INF = -1e30


def _merge(m, l, acc, s, v):
    """Online-softmax merge of one incoming score block."""
    m_new = jnp.maximum(m, s.max(axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + p.sum(axis=-1)
    pv = jnp.einsum("bkgqs,bskd->bkgqd", p, v.astype(jnp.float32))
    acc_new = acc * corr[..., None] + pv
    return m_new, l_new, acc_new


def ring_attention_local(q, k, v, *, axis, causal=True):
    """Body to run INSIDE shard_map. q: (B, Sq_local, H, hd); k, v:
    (B, Sk_local, KV, hd), sequence sharded over `axis` in device
    order. Returns (B, Sq_local, H, hd).
    """
    P = _axis_size(axis)
    idx = jax.lax.axis_index(axis)
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    scale = hd ** -0.5
    qf = (q.astype(jnp.float32) * scale).reshape(B, Sq, KV, G, hd)
    q_pos = idx * Sq + jnp.arange(Sq, dtype=jnp.int32)

    perm = [(i, (i + 1) % P) for i in range(P)]

    def step(carry, t):
        m, l, acc, kc, vc = carry
        # segment currently held arrived from device (idx - t) % P
        src = jax.lax.rem(idx - t + P, P)
        k_pos = src * Sk + jnp.arange(Sk, dtype=jnp.int32)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qf, kc.astype(jnp.float32))
        if causal:
            mask = q_pos[:, None] >= k_pos[None, :]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
        m, l, acc = _merge(m, l, acc, s, vc)
        # rotate k/v to the next device (skip after the last step)
        kc = jax.lax.ppermute(kc, axis, perm)
        vc = jax.lax.ppermute(vc, axis, perm)
        return (m, l, acc, kc, vc), None

    m0 = jnp.full((B, KV, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KV, G, Sq, hd), jnp.float32)
    (m, l, acc, _, _), _ = jax.lax.scan(
        step, (m0, l0, a0, k, v), jnp.arange(P))
    l = jnp.maximum(l, 1e-30)
    out = (acc / l[..., None]).astype(q.dtype)     # (B, KV, G, Sq, hd)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd)


def ring_attention(q, k, v, *, mesh, axis="model", causal=True,
                   batch_axis=None):
    """Convenience wrapper: shard q/k/v on the sequence dim over `axis`
    (and optionally batch over `batch_axis`), run the ring body.

    q: (B, S, H, hd) GLOBAL arrays (pjit-land).
    """
    Pspec = jax.sharding.PartitionSpec
    seq_spec = Pspec(batch_axis, axis, None, None)

    fn = _shard_map(
        functools.partial(ring_attention_local, axis=axis, causal=causal),
        mesh=mesh,
        in_specs=(seq_spec, seq_spec, seq_spec),
        out_specs=seq_spec)
    return fn(q, k, v)
