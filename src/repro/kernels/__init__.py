"""TPU Pallas kernels for the compute hot-spots.

  ff_dense        — the FF-MLP hot loop: fused matmul -> ReLU -> goodness
                    (one pass computes the layer output AND the per-row
                    sum-of-squares the FF loss needs).
  ff_dense_vjp    — custom_vjp around ff_dense with a fused Pallas
                    backward kernel (dw/db/dx from resident tiles), so
                    jax.grad of the FF objective stays on the fused path.
  flash_attention — blockwise online-softmax attention (GQA / causal /
                    sliding-window) for the transformer archs.
  mamba2_ssd      — chunked SSD dual-form scan (intra-chunk quadratic +
                    carried state) for Mamba-2.

Each kernel ships as <name>.py (pl.pallas_call + BlockSpec) plus a
pure-jnp oracle in ref.py. Dispatch is a two-layer system:

  registry.py — named impls per op with platform predicates; new
                backends (e.g. a Pallas-Triton GPU lowering) are
                registered, not patched into an if-chain.
  autotune.py — measure-many/pick-fastest block-shape tuner with a
                persisted JSON tuning table (REPRO_TUNE_TABLE), gated
                on the 1e-4 oracle error.
  ops.py      — the jit-friendly entry points model code calls, with a
                shared ``impl="auto" | <registered name>`` contract;
                "auto" resolves through the tuning table then the
                registry's platform default.

The FF-MLP model code calls the fused path for real: ``repro.core.
ff_mlp`` trains and predicts through ``ops.ff_dense`` with the
config-driven ``kernel_impl`` switch (Pallas runs under interpret=True
off-TPU). The kernels are validated against the oracles in tests/ and
gated to <= 1e-4 by ``benchmarks/run.py``.
"""
