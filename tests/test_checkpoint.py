"""Direct tests for repro.checkpoint (previously only covered through
the property suite): save/restore round-trip incl. the bf16 upcast
path, __step__ handling, tmp-file atomicity, and the strict=/meta=
behavior the PFF executor's chapter manifests rely on."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint


def _tree():
    return {"layers": [{"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                        "b": jnp.ones((3,))}],
            "state": (jnp.full((2, 2), 2.5), jnp.zeros((4,)))}


def test_roundtrip_bit_exact(tmp_path):
    import jax

    path = str(tmp_path / "ck.npz")
    tree = _tree()
    checkpoint.save(path, tree, step=12)
    restored, step = checkpoint.restore(path, tree)
    assert step == 12
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        assert a.dtype == b.dtype
        assert bool(jnp.array_equal(a, b))
    # tuples restored as tuples, lists as lists (template treedef)
    assert isinstance(restored["state"], tuple)
    assert isinstance(restored["layers"], list)


def test_step_none_roundtrip(tmp_path):
    path = str(tmp_path / "ck.npz")
    tree = {"w": jnp.ones((2,))}
    checkpoint.save(path, tree)
    _, step = checkpoint.restore(path, tree)
    assert step is None


def test_bf16_upcast_roundtrip(tmp_path):
    """bf16 leaves are persisted as lossless f32 and cast back to the
    template's dtype on restore."""
    path = str(tmp_path / "ck.npz")
    tree = {"w": jnp.asarray([1.5, -2.25, 3.0], jnp.bfloat16)}
    checkpoint.save(path, tree, step=1)
    restored, _ = checkpoint.restore(path, tree)
    assert restored["w"].dtype == jnp.bfloat16
    assert bool(jnp.array_equal(restored["w"], tree["w"]))
    # the archive itself holds f32 (np can't represent bf16)
    with np.load(path) as z:
        assert z["w"].dtype == np.float32


def test_atomic_no_tmp_left_behind(tmp_path):
    path = str(tmp_path / "sub" / "ck.npz")
    checkpoint.save(path, {"w": jnp.ones((2,))}, step=3)
    assert os.path.exists(path)
    assert not os.path.exists(path + ".tmp")
    assert os.listdir(os.path.dirname(path)) == ["ck.npz"]


def test_missing_key_and_shape_mismatch(tmp_path):
    path = str(tmp_path / "ck.npz")
    checkpoint.save(path, {"w": jnp.ones((2,))})
    with pytest.raises(KeyError, match="missing"):
        checkpoint.restore(path, {"w": jnp.ones((2,)), "b": jnp.ones((1,))})
    with pytest.raises(ValueError, match="shape"):
        checkpoint.restore(path, {"w": jnp.ones((3,))})


def test_strict_rejects_unconsumed_keys(tmp_path):
    path = str(tmp_path / "ck.npz")
    full = {"w": jnp.ones((2,)), "extra": jnp.zeros((1,))}
    checkpoint.save(path, full, step=5)
    sub = {"w": jnp.ones((2,))}
    # lenient (default): extras silently ignored — historical behavior
    restored, step = checkpoint.restore(path, sub)
    assert step == 5 and bool(jnp.array_equal(restored["w"], full["w"]))
    # strict: unconsumed keys are an error naming the leftovers
    with pytest.raises(ValueError, match="extra"):
        checkpoint.restore(path, sub, strict=True)
    # __step__/__meta__ never count as unconsumed
    checkpoint.restore(path, full, strict=True)


def test_meta_roundtrip(tmp_path):
    path = str(tmp_path / "ck.npz")
    meta = {"chapter": 3, "schedule": "all_layers", "ver": [3, 3],
            "nested": {"ok": True}}
    tree = {"w": jnp.ones((2,))}
    checkpoint.save(path, tree, step=3, meta=meta)
    restored, step, got = checkpoint.restore(path, tree, strict=True,
                                             with_meta=True)
    assert got == meta and step == 3
    # without with_meta the historical 2-tuple signature is preserved
    out = checkpoint.restore(path, tree)
    assert len(out) == 2
    # absent meta reads back as None
    checkpoint.save(path, tree)
    _, _, none_meta = checkpoint.restore(path, tree, with_meta=True)
    assert none_meta is None


def test_meta_must_be_json_serializable(tmp_path):
    path = str(tmp_path / "ck.npz")
    with pytest.raises(TypeError):
        checkpoint.save(path, {"w": jnp.ones((2,))},
                        meta={"bad": jnp.ones((2,))})
    # the failed save must not leave a tmp file behind either
    assert not os.path.exists(path + ".tmp")
