"""Logical-to-mesh sharding rules.

Single-pod production mesh: ``(data=16, model=16)``.
Multi-pod: ``(pod=2, data=16, model=16)`` — baseline shards batch on
``(pod, data)`` (pure DP over pods) and parameters exactly as single-pod
(replicated over ``pod``). The beyond-paper PFF mode instead uses ``pod``
as the pipeline-stage axis (see ``repro.core.pff_pod``).

Parameter rules are name-based over the pytree path, with a divisibility
guard: any named mesh axis that does not divide the corresponding dim is
dropped (-> replicated) so every assigned architecture lowers (e.g. KV=4
heads cannot shard over model=16; h2o-danube head_dim=120 cannot shard
over 16).

Conventions (leading ``R`` = stacked scan axis, never sharded):
  embed (V, d)            -> (model, data)        vocab-parallel + FSDP
  lm_head (d, V)          -> (data, model)
  attn wq (R, d, H, hd)   -> (-, data, model, -)  head-parallel + FSDP
  attn wk/wv (R, d,KV,hd) -> (-, data, model|-, model if KV undiv)
  attn wo (R, H, hd, d)   -> (-, model, -, data)
  mlp wi/wg (R, d, ff)    -> (-, data, model)
  mlp wo (R, ff, d)       -> (-, model, data)
  moe wi/wg (R, E, d, ff) -> (-, model, data, -)  expert-parallel + ZeRO
  moe wo (R, E, ff, d)    -> (-, model, -, data)
  ssm/rglru projections   -> (-, data, model) ; out_proj (-, model, data)
  norms / scalars         -> replicated
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def shard_map(body, *, mesh, in_specs, out_specs, check: bool = False):
    """Version-compat ``shard_map``: jax >= 0.6 exposes ``jax.shard_map``
    (kw ``check_vma``); this container's 0.4.x only has the experimental
    one (kw ``check_rep``). One shim for every call site."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check)


def _fit(spec, shape, mesh):
    """Drop axis names that don't divide the dim; None-pad to rank."""
    names = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, name in zip(shape, names):
        if name is None:
            out.append(None)
            continue
        size = 1
        for n in (name if isinstance(name, tuple) else (name,)):
            size *= mesh.shape[n]
        out.append(name if dim % size == 0 else None)
    return P(*out)


# desired spec by (parent-key or leaf-name); checked most-specific-first
_RULES = [
    # (predicate on path names, spec builder)  — R axis is always first for
    # group params; non-group params (embed/lm_head) have no R axis.
    ("embed",      lambda: P("model", "data")),
    ("lm_head",    lambda: P("data", "model")),
    ("final_norm", lambda: P(None)),
    ("enc_norm",   lambda: P(None)),
]

_GROUP_LEAF = {
    "wq":       (None, "data", "model", None),
    "wk":       (None, "data", "model", None),
    "wv":       (None, "data", "model", None),
    "wo":       None,   # context-dependent: attn (R,H,hd,d) vs mlp (R,ff,d)
    "wi":       None,   # mlp (R,d,ff) vs moe (R,E,d,ff)
    "wg":       None,
    "bq":       (None, "model", None),
    "bk":       (None, "model", None),
    "bv":       (None, "model", None),
    "q_norm":   (None, None),
    "k_norm":   (None, None),
    "gate":     (None,),
    "router":   (None, None, None),
    "in_proj":  (None, "data", "model"),
    "conv_w":   (None, None, "model"),
    "A_log":    (None, None),
    "D":        (None, None),
    "dt_bias":  (None, None),
    "norm":     (None, "model"),
    "out_proj": (None, "model", "data"),
    "x_branch": (None, "data", "model"),
    "gate_branch": (None, "data", "model"),
    "w_a":      (None, "data", "model"),
    "w_x":      (None, "data", "model"),
    "lambda":   (None, "model"),
    "norm1":    (None, None),
    "norm2":    (None, None),
    "norm_x":   (None, None),
}


def _leaf_spec(path_names, shape):
    name = path_names[-1]
    if name in ("wi", "wg"):
        if len(shape) == 4:                       # moe (R, E, d, ff)
            return (None, "model", "data", None)
        return (None, "data", "model")            # dense (R, d, ff)
    if name == "wo":
        if len(shape) == 4:
            if "attn" in path_names or "xattn" in path_names:
                return (None, "model", None, "data")   # attn (R,H,hd,d)
            return (None, "model", None, "data")       # moe (R,E,ff,d)
        return (None, "model", "data")                 # mlp (R, ff, d)
    if name in _GROUP_LEAF and _GROUP_LEAF[name] is not None:
        return _GROUP_LEAF[name]
    return tuple(None for _ in shape)


def param_specs(params, mesh):
    """PartitionSpec pytree matching ``params`` (works for opt m/v too)."""
    def spec_for(path, leaf):
        names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        for key, builder in _RULES:
            if names and names[0] == key:
                return _fit(builder(), leaf.shape, mesh)
        return _fit(P(*_leaf_spec(names, leaf.shape)), leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec_for, params)


def shardings(tree_specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_axes(mesh):
    """Mesh axes that shard the batch dimension."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def data_spec(mesh, rank, batch_dim=0):
    """Spec for a batch-dim-sharded array of given rank."""
    ba = batch_axes(mesh)
    dims = [None] * rank
    dims[batch_dim] = ba if len(ba) > 1 else ba[0]
    return P(*dims)


def cache_specs_tree(caches, mesh, *, seq_axis_model=False):
    """Shardings for decode caches.

    KV caches (R, B, S, KV, hd): batch -> data axes; when
    ``seq_axis_model`` shard S on 'model' (used for batch=1 long-context,
    where batch cannot use the data axis).
    SSM/RG-LRU states (R, B, ...): batch -> data, trailing dims on model
    where divisible.
    """
    ba = batch_axes(mesh)
    b_name = ba if len(ba) > 1 else ba[0]

    def spec_for(leaf):
        shape = leaf.shape
        if len(shape) >= 3:
            want = [None, b_name]
            if len(shape) == 5:                      # (R, B, S, KV, hd)
                want += ["model" if seq_axis_model else None, "model"
                         if not seq_axis_model else None, None]
            elif len(shape) == 4:                    # (R, B, H, ...) state
                want += ["model", None]
            else:
                want += [None] * (len(shape) - 2)
            return _fit(P(*want[:len(shape)]), shape, mesh)
        return P(*([None] * len(shape)))

    return jax.tree.map(spec_for, caches)
