"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

    r_t = sigmoid(W_a x_t);  i_t = sigmoid(W_x x_t)
    a_t = exp(-c * softplus(Lambda) * r_t)            (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Block: y = out_proj( gelu(gate_branch) * RG-LRU(conv1d(x_branch)) ).
Prefill uses an associative scan (log-depth); decode is a single-step
recurrence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common

_C = 8.0


def init(key, cfg):
    g = cfg.rglru
    d = cfg.d_model
    W = g.lru_width or d
    ks = jax.random.split(key, 7)
    dtype = common.dtype_of(cfg)
    # Lambda init so that a^c in [0.9, 0.999] (Griffin appendix)
    u = jax.random.uniform(ks[4], (W,), jnp.float32, 0.9 ** 2, 0.999 ** 2)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / (2 * _C)))   # inv softplus
    return {
        "x_branch": common.dense_init(ks[0], (d, W), dtype),
        "gate_branch": common.dense_init(ks[1], (d, W), dtype),
        "conv_w": (jax.random.normal(ks[2], (g.conv_width, W), jnp.float32)
                   * 0.1).astype(dtype),
        "w_a": common.dense_init(ks[3], (W, W), dtype),
        "w_x": common.dense_init(ks[5], (W, W), dtype),
        "lambda": lam,
        "out_proj": common.dense_init(ks[6], (W, d), dtype),
    }


def _gates(p, x):
    r = jax.nn.sigmoid((x @ p["w_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid((x @ p["w_x"]).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lambda"]) * r     # (..., W), <= 0
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta * i * x.astype(jnp.float32)


def forward(p, cfg, x, h0=None, conv0=None):
    """x: (B, S, d) -> (B, S, d); returns (y, h_T, conv_tail)."""
    from repro.models.ssm import _causal_conv
    g = cfg.rglru
    xb = x @ p["x_branch"]
    gate = x @ p["gate_branch"]
    if conv0 is not None:
        ext = jnp.concatenate([conv0, xb], axis=1)
        xb = _causal_conv(ext, p["conv_w"])[:, conv0.shape[1]:]
        conv_tail = ext[:, -(g.conv_width - 1):]
    else:
        conv_tail = xb[:, -(g.conv_width - 1):]     # raw (pre-conv) tail
        xb = _causal_conv(xb, p["conv_w"])
    a, b = _gates(p, xb)                               # (B, S, W) f32

    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))
    aa, hh = jax.lax.associative_scan(
        lambda e1, e2: (e2[0] * e1[0], e2[0] * e1[1] + e2[1]),
        (a, b), axis=1)
    y = hh.astype(x.dtype) * jax.nn.gelu(gate.astype(jnp.float32)
                                         ).astype(x.dtype)
    return y @ p["out_proj"], hh[:, -1], conv_tail


def init_cache(cfg, batch, dtype):
    g = cfg.rglru
    W = g.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, W), jnp.float32),
        "conv": jnp.zeros((batch, g.conv_width - 1, W), dtype),
    }


def decode_step(p, cfg, cache, x):
    """x: (B, d) -> (y (B, d), new cache)."""
    xb = x @ p["x_branch"]
    gate = x @ p["gate_branch"]
    conv_in = jnp.concatenate([cache["conv"], xb[:, None]], axis=1)
    xb = jnp.einsum("bwc,wc->bc", conv_in.astype(jnp.float32),
                    p["conv_w"].astype(jnp.float32)).astype(x.dtype)
    a, b = _gates(p, xb)
    h = a * cache["h"] + b
    y = h.astype(x.dtype) * jax.nn.gelu(gate.astype(jnp.float32)
                                        ).astype(x.dtype)
    return y @ p["out_proj"], {"h": h, "conv": conv_in[:, 1:]}
