"""Deterministic fault injection + resilience policy for the PFF executor.

A production posture for the real executor (``repro.core.pff_exec``)
needs survival, not just speed — and survival logic is untestable unless
every failure mode is REPRODUCIBLE. This module provides that surface:

* ``Fault`` / ``FaultPlan`` — a seeded, schedule-addressable plan of
  failures. Each fault addresses the executor's own task coordinates
  (``kind, layer, chapter, node`` — the same addressing as
  ``pff_dag.Task``) or a hand-off transfer slot, so a test or benchmark
  can say "crash train(layer 0, chapter 1) on its owning node, twice"
  and get exactly that, every run. Fault kinds:

    crash            raise ``InjectedFault`` at task entry (before any
                     state mutation — the executor retries are clean)
    delay            sleep ``delay_ms`` at task entry on the owning node
    drop_handoff     a double-buffered transfer never arrives (the
                     consumer falls back to an on-demand pull)
    corrupt_handoff  the transferred bits arrive poisoned (NaNs) with
                     the integrity flag set — modelling a checksum
                     failure on receive; the consumer must detect it and
                     re-pull, never serve the poisoned tree
    kill             hard-kill the process (``os._exit(KILL_EXIT)``) at
                     chapter ``chapter`` — ``phase="mid"`` mid-chapter
                     (after its first train task), ``phase="post"``
                     right after the chapter checkpoint is on disk

* ``ResilienceConfig`` — the policy knob passed to
  ``api.fit(..., backend="executor", resilience=...)``: chapter-granular
  checkpointing (dir / cadence / retention), retry budget + exponential
  backoff, the fault plan to inject, and the elastic-federated
  membership callback.

* ``NAMED_PLANS`` — parameterized example plans (``named_plan``)
  surfaced as ``--fault-plan`` choices on the ``pff_exec`` CLI, so any
  injected failure is reproducible from the command line.

Determinism contract: a ``FaultPlan`` is pure data plus per-fault
trigger counters — matching consumes a trigger (``times``; ``-1`` means
every occurrence), and the executor walks tasks in the DAG's canonical
order, so a plan fires at exactly the same points in every run.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Callable, List, Optional, Sequence

KINDS = ("crash", "delay", "drop_handoff", "corrupt_handoff", "kill")

#: Exit code of a process hard-killed by a ``kill`` fault — distinctive,
#: so the kill-then-resume tests can tell an injected kill from a crash.
KILL_EXIT = 17


class InjectedFault(RuntimeError):
    """Raised by an injected ``crash`` fault. The executor's retry /
    reassignment machinery catches EXACTLY this type — real errors
    still propagate."""


@dataclasses.dataclass
class Fault:
    """One addressable failure. ``None`` fields are wildcards.

    ``task``/``layer``/``chapter``/``node`` address executor tasks for
    ``crash``/``delay`` (task in train|head|neg_gen|round); for the
    hand-off kinds ``task`` matches the slot name ("state" | "params" |
    "head" | "neg"), ``layer`` the slot's layer, ``chapter`` the
    producing version and ``node`` the destination. ``kill`` uses only
    ``chapter`` + ``phase``.
    """
    kind: str
    task: Optional[str] = None
    layer: Optional[int] = None
    chapter: Optional[int] = None
    node: Optional[int] = None
    times: int = 1                 # trigger budget; -1 = every occurrence
    delay_ms: float = 0.0          # kind == "delay"
    phase: str = "mid"             # kind == "kill": "mid" | "post"

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {KINDS}")
        if self.kind == "kill" and self.phase not in ("mid", "post"):
            raise ValueError(f"kill phase must be 'mid' or 'post', "
                             f"got {self.phase!r}")


@dataclasses.dataclass
class FaultPlan:
    """A seeded list of faults with per-fault trigger counters.

    The executor consults the plan at well-defined points (task entry,
    hand-off prefetch, chapter boundaries); each successful match
    consumes one trigger. ``fired`` counts consumed triggers per kind —
    what ``ExecResult.resilience["faults_injected"]`` reports.
    """
    faults: List[Fault] = dataclasses.field(default_factory=list)
    seed: int = 0

    def __post_init__(self):
        self.reset()

    def reset(self):
        """Restore every fault's trigger budget (plans are reusable)."""
        self._remaining = [f.times for f in self.faults]
        self.fired = {}

    # ---- matching --------------------------------------------------------
    def _match(self, kind, task=None, layer=None, chapter=None, node=None):
        """First armed fault matching all non-None fields; consumes one
        trigger and returns the Fault (None = no match)."""
        for i, f in enumerate(self.faults):
            if f.kind != kind or self._remaining[i] == 0:
                continue
            if f.task is not None and f.task != task:
                continue
            if f.layer is not None and f.layer != layer:
                continue
            if f.chapter is not None and f.chapter != chapter:
                continue
            if f.node is not None and f.node != node:
                continue
            if self._remaining[i] > 0:
                self._remaining[i] -= 1
            self.fired[kind] = self.fired.get(kind, 0) + 1
            return f
        return None

    def should_crash(self, task, layer, chapter, node) -> bool:
        return self._match("crash", task, layer, chapter, node) is not None

    def delay_s(self, task, layer, chapter, node) -> float:
        f = self._match("delay", task, layer, chapter, node)
        return f.delay_ms / 1000.0 if f is not None else 0.0

    def handoff_action(self, name, node, version) -> Optional[str]:
        """"drop" / "corrupt" / None for a prefetch of slot ``name``
        (a tuple like ("state", k) or ("head",)) onto ``node`` at
        producing-chapter ``version``."""
        slot, layer = name[0], (name[1] if len(name) > 1 else None)
        for kind in ("drop_handoff", "corrupt_handoff"):
            if self._match(kind, slot, layer, version, node) is not None:
                return "drop" if kind == "drop_handoff" else "corrupt"
        return None

    def kill_now(self, chapter, phase) -> bool:
        for i, f in enumerate(self.faults):
            if (f.kind == "kill" and self._remaining[i] != 0
                    and f.phase == phase
                    and (f.chapter is None or f.chapter == chapter)):
                if self._remaining[i] > 0:
                    self._remaining[i] -= 1
                self.fired["kill"] = self.fired.get("kill", 0) + 1
                return True
        return False

    # ---- serialization (CLI / subprocess tests) --------------------------
    def to_json(self) -> str:
        return json.dumps({"seed": self.seed,
                           "faults": [dataclasses.asdict(f)
                                      for f in self.faults]})

    @classmethod
    def from_json(cls, s: str) -> "FaultPlan":
        d = json.loads(s)
        return cls(faults=[Fault(**f) for f in d.get("faults", [])],
                   seed=d.get("seed", 0))


@dataclasses.dataclass
class ResilienceConfig:
    """Resilience policy for ``PFFExecutor`` / ``api.fit(...,
    resilience=...)``.

    checkpoint_dir: where chapter manifests go (None = no checkpointing).
    checkpoint_every: write one manifest every N completed chapters (the
        last chapter is always written so a finished run is resumable).
    keep_last: retention — older chapter manifests are pruned.
    max_retries: per-task retry budget for injected crashes; on
        exhaustion the node is declared dead (all_layers/single_layer
        reassign its tasks to a surviving device; federated drops its
        shard).
    backoff_base_s/backoff_factor: exponential backoff between retries
        (attempt i sleeps base * factor**i — deterministic, no jitter,
        so fault tests are reproducible).
    fault_plan: the deterministic failures to inject (None = none).
    membership: elastic Federated PFF — callable ``round -> iterable of
        live node ids``; live nodes train their own shard from the
        round-start model in parallel and the aggregator averages
        weighted by live shard sizes (``pff.weighted_average_trees``).
    """
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 1
    keep_last: int = 3
    max_retries: int = 2
    backoff_base_s: float = 0.05
    backoff_factor: float = 2.0
    fault_plan: Optional[FaultPlan] = None
    membership: Optional[Callable[[int], Sequence[int]]] = None


# ---------------------------------------------------------------------------
# Named plans: reproducible failures from the command line
# (`python -m repro.core.pff_exec --fault-plan <name>`).
# ---------------------------------------------------------------------------

def _crash_once(splits, n_layers, num_nodes):
    # one transient crash of the second chapter's first train task; the
    # first retry succeeds -> run stays bit-exact
    return FaultPlan([Fault("crash", task="train", layer=0,
                            chapter=min(1, splits - 1), times=1)])


def _dead_node(splits, n_layers, num_nodes):
    # the last node fails permanently: retries exhaust, its tasks are
    # reassigned (all_layers/single_layer) or its shard dropped
    # (federated)
    return FaultPlan([Fault("crash", node=max(num_nodes - 1, 0),
                            times=-1)])


def _delay_node(splits, n_layers, num_nodes):
    # a straggler: every task on node 0 starts 30 ms late
    return FaultPlan([Fault("delay", node=0, delay_ms=30.0, times=-1)])


def _drop_handoff(splits, n_layers, num_nodes):
    # every double-buffered transfer is lost; consumers must fall back
    # to on-demand pulls and the weight stream must not change
    return FaultPlan([Fault("drop_handoff", times=-1)])


def _corrupt_handoff(splits, n_layers, num_nodes):
    # every transfer arrives poisoned; the version/integrity gate must
    # detect and re-pull — a served poisoned tree would NaN the weights
    return FaultPlan([Fault("corrupt_handoff", times=-1)])


def _kill_mid(splits, n_layers, num_nodes):
    # hard-kill mid-chapter (after the chapter's first train task) —
    # resume must replay the partially-executed chapter bit-exactly
    return FaultPlan([Fault("kill", chapter=max(1, splits // 2),
                            phase="mid", times=1)])


def _kill_post(splits, n_layers, num_nodes):
    # hard-kill right after the chapter checkpoint hits disk
    return FaultPlan([Fault("kill", chapter=max(1, splits // 2),
                            phase="post", times=1)])


NAMED_PLANS = {
    "crash_once": _crash_once,
    "dead_node": _dead_node,
    "delay_node": _delay_node,
    "drop_handoff": _drop_handoff,
    "corrupt_handoff": _corrupt_handoff,
    "kill_mid": _kill_mid,
    "kill_post": _kill_post,
}


def named_plan(name, *, splits, n_layers, num_nodes) -> FaultPlan:
    """Instantiate one of ``NAMED_PLANS`` for a concrete run shape."""
    try:
        build = NAMED_PLANS[name]
    except KeyError:
        raise KeyError(f"unknown fault plan {name!r}; known: "
                       f"{', '.join(sorted(NAMED_PLANS))}") from None
    return build(splits, n_layers, num_nodes)
