"""Real multi-device PFF executor tests.

The executor needs several host devices
(XLA_FLAGS=--xla_force_host_platform_device_count=4), but conftest keeps
the in-process test runner on the real single CPU device on purpose —
so the multi-device runs happen in ONE subprocess that sweeps the whole
schedule matrix (repro.core.pff_exec._MATRIX): All-Layers (random and
adaptive+softmax), Federated, and Single-Layer, each checked for
weight-stream BIT-EQUALITY against the sequential trainer, plus the
simulate-vs-measured makespan sanity bound. Every matrix case uses an
n_train that is NOT divisible by the batch size, so the tail-batch
path is exercised end to end. The _AB_CASES rows additionally run the
executor with the double-buffered hand-off DISABLED and require the
overlap-on and overlap-off weight streams to be bit-identical (and the
overlap run to actually hit its prefetched transfer slots).

In-process tests cover what works on one device: the executor's
argument validation and the DAG module it shares with the simulator.
"""
import os
import subprocess
import sys

import pytest

from repro.core import pff_dag

_SRC = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                    os.pardir, "src")


def test_exec_weight_stream_bit_exact_matrix():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m", "repro.core.pff_exec", "--matrix"],
        capture_output=True, text=True, env=env, timeout=540)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "bit-exact vs the sequential trainer" in r.stdout


def test_executor_rejects_bad_args():
    from repro import data as data_lib
    from repro.configs.ff_mlp import FFMLPConfig
    from repro.core import pff_exec

    task = data_lib.mnist_like(n_train=200, n_test=50)
    cfg = FFMLPConfig(layer_sizes=(784, 32), epochs=2, splits=2)
    with pytest.raises(ValueError):
        pff_exec.PFFExecutor(cfg, task, "gpipe", 1)
    with pytest.raises(ValueError):
        pff_exec.PFFExecutor(cfg, task, "sequential", 2)
    # unregistered strategy names fail fast with the registry's error
    with pytest.raises(KeyError):
        pff_exec.PFFExecutor(
            cfg.__class__(layer_sizes=(784, 32), goodness_fn="nope"),
            task, "all_layers", 1)


def test_executor_sequential_single_device_runs():
    """N=1 needs no faked devices — the executor must work in-process
    (via the facade) and still match the canonical trainer bit-exactly."""
    import jax.numpy as jnp
    from repro import api, data as data_lib
    from repro.configs.ff_mlp import FFMLPConfig

    task = data_lib.mnist_like(n_train=200, n_test=50)
    cfg = FFMLPConfig(layer_sizes=(784, 64), epochs=2, splits=2,
                      neg_mode="random", classifier="goodness",
                      batch_size=64, seed=0)
    ref = api.fit(cfg, task, backend="sequential")
    res = api.fit(cfg, task, backend="executor", schedule="sequential",
                  num_nodes=1)
    for lp_ref, lp_ex in zip(ref.params["layers"], res.params["layers"]):
        assert bool(jnp.array_equal(lp_ref["w"], lp_ex["w"]))
        assert bool(jnp.array_equal(lp_ref["b"], lp_ex["b"]))
    assert res.makespan > 0


def test_executor_perf_opt_single_device_bit_exact():
    """The §4.4 Performance-Optimized path on the executor: layer AND
    local-head weight streams must match the sequential trainer."""
    from repro import api, data as data_lib
    from repro.configs.ff_mlp import FFMLPConfig
    from repro.core import pff_exec

    task = data_lib.mnist_like(n_train=200, n_test=50)
    cfg = FFMLPConfig(layer_sizes=(784, 48, 48), epochs=2, splits=2,
                      goodness_fn="perf_opt", batch_size=64, seed=0)
    ref = api.fit(cfg, task, backend="sequential")
    res = api.fit(cfg, task, backend="executor", schedule="sequential",
                  num_nodes=1)
    assert pff_exec.params_bit_equal(ref.params, res.params,
                                     with_local_heads=True)
    assert res.test_acc == ref.test_acc


# ---------------------------------------------------------------------------
# The shared DAG module (consumed by both simulator and executor)
# ---------------------------------------------------------------------------

def test_dag_topological_order():
    """build_tasks must list every dep before its dependent."""
    seen = set()
    for has_head, has_neg, has_local in [(False, False, False),
                                         (True, True, False),
                                         (False, False, True)]:
        seen.clear()
        for t in pff_dag.build_tasks(3, 4, has_head=has_head,
                                     has_neg=has_neg,
                                     has_local_heads=has_local):
            for d in pff_dag.deps(t, 3, has_head=has_head,
                                  has_neg=has_neg, strict_neg=True,
                                  has_local_heads=has_local):
                assert d in seen, (t, d)
            seen.add(t)


def test_dag_local_head_is_per_layer_dependent():
    """§4.4: each local_head(k, c) depends on its own train task and its
    previous-chapter self, and trains on the same node as train(k, c)."""
    t = pff_dag.Task("local_head", 1, 2)
    d = pff_dag.deps(t, 3)
    assert pff_dag.Task("train", 1, 2) in d
    assert pff_dag.Task("local_head", 1, 1) in d
    # ...and the chapter-c train task waits for chapter-(c-1)'s local
    # head, whose weights it backprops through
    assert pff_dag.Task("local_head", 1, 1) in pff_dag.deps(
        pff_dag.Task("train", 1, 2), 3, has_local_heads=True)
    tasks = pff_dag.build_tasks(3, 2, has_local_heads=True)
    assert pff_dag.Task("local_head", 0, 0) in tasks


def test_dag_node_assignments_match_paper():
    # all_layers: node per chapter (Algorithm 2)
    assert [pff_dag.node_of("all_layers", 4, layer=k, chapter=6)
            for k in range(4)] == [2] * 4
    # single_layer: node per layer (Algorithm 1)
    assert [pff_dag.node_of("single_layer", 4, layer=k, chapter=6)
            for k in range(4)] == [0, 1, 2, 3]
    # negatives: single_layer publishes from the LAST node, all_layers
    # regenerates on the chapter's own node
    assert pff_dag.neg_node_of("single_layer", 4, chapter=1) == 3
    assert pff_dag.neg_node_of("all_layers", 4, chapter=1) == 1
    with pytest.raises(ValueError):
        pff_dag.node_of("gpipe", 4, layer=0, chapter=0)


def test_dag_strict_neg_gates_next_chapter():
    t = pff_dag.Task("train", 0, 2)
    d_loose = pff_dag.deps(t, 2, has_neg=True, strict_neg=False)
    d_strict = pff_dag.deps(t, 2, has_neg=True, strict_neg=True)
    assert pff_dag.Task("neg_gen", -1, 1) not in d_loose
    assert pff_dag.Task("neg_gen", -1, 1) in d_strict


# ---------------------------------------------------------------------------
# Double-buffered hand-off targets (what the executor prefetches)
# ---------------------------------------------------------------------------

def test_handoff_targets_all_layers_next_chapter_node():
    """all_layers: layer k's full state is consumed by the NEXT
    chapter's node; there are no within-chapter cross-node consumers."""
    nxt, params = pff_dag.handoff_targets(
        "all_layers", 4, n_layers=3, splits=4, layer=1, chapter=1,
        has_head=True, has_neg=True)
    assert nxt == 2 and params == []
    # last chapter: nothing left to hand off
    nxt, params = pff_dag.handoff_targets(
        "all_layers", 4, n_layers=3, splits=4, layer=1, chapter=3)
    assert nxt is None and params == []


def test_handoff_targets_single_layer_param_fanout():
    """single_layer: layer k stays on node k across chapters (no state
    hand-off) but its params fan out to every later layer's forward
    recompute plus the head and neg_gen nodes."""
    nxt, params = pff_dag.handoff_targets(
        "single_layer", 4, n_layers=4, splits=3, layer=0, chapter=1,
        has_head=True, has_neg=True)
    assert nxt is None          # node 0 trains layer 0 every chapter
    assert params == [1, 2, 3]  # recompute by 1,2; head on 3; neg on 3
    # the last layer's params go only to head/neg (both node 3 == src)
    nxt, params = pff_dag.handoff_targets(
        "single_layer", 4, n_layers=4, splits=3, layer=3, chapter=1,
        has_head=True, has_neg=True)
    assert nxt is None and params == []


def test_handoff_targets_sequential_is_empty():
    nxt, params = pff_dag.handoff_targets(
        "sequential", 1, n_layers=3, splits=4, layer=0, chapter=0,
        has_head=True, has_neg=True)
    assert nxt is None and params == []


def test_chapter_train_nodes():
    assert pff_dag.chapter_train_nodes("all_layers", 4, 3, chapter=6) \
        == [2]
    assert pff_dag.chapter_train_nodes("single_layer", 2, 3, chapter=0) \
        == [0, 1]
    assert pff_dag.chapter_train_nodes("sequential", 1, 3, chapter=5) \
        == [0]


def test_executor_overlap_off_single_device_bit_exact():
    """overlap=False must reproduce the same stream in-process too (the
    multi-node on/off A-B runs in the subprocess matrix)."""
    import jax.numpy as jnp
    from repro import api, data as data_lib
    from repro.configs.ff_mlp import FFMLPConfig

    task = data_lib.mnist_like(n_train=200, n_test=50)
    cfg = FFMLPConfig(layer_sizes=(784, 64), epochs=2, splits=2,
                      neg_mode="random", classifier="goodness",
                      batch_size=64, seed=0)
    on = api.fit(cfg, task, backend="executor", schedule="sequential",
                 num_nodes=1)
    off = api.fit(cfg, task, backend="executor", schedule="sequential",
                  num_nodes=1, overlap=False)
    for lp_on, lp_off in zip(on.params["layers"], off.params["layers"]):
        assert bool(jnp.array_equal(lp_on["w"], lp_off["w"]))
        assert bool(jnp.array_equal(lp_on["b"], lp_off["b"]))
    assert off.raw.handoff["prefetch_issued"] == 0
