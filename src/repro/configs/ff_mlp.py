"""The paper's own architecture: [784, 2000, 2000, 2000, 2000] ReLU MLP
trained with Forward-Forward on MNIST (Hinton 2022 / PFF paper §5.1)."""
import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class FFMLPConfig:
    layer_sizes: Tuple[int, ...] = (784, 2000, 2000, 2000, 2000)
    num_classes: int = 10
    theta: float = 2.0              # goodness threshold
    lr_ff: float = 0.01             # Adam lr for FF layers (paper §5.1)
    lr_softmax: float = 1e-4        # Adam lr for the softmax head
    batch_size: int = 64
    epochs: int = 100
    splits: int = 100               # chapters (paper: S=100)
    cooldown_after: float = 0.5     # lr cooldown after 50% of epochs
    neg_mode: str = "adaptive"      # adaptive | fixed | random
    classifier: str = "goodness"    # goodness | softmax
    goodness_fn: str = "sumsq"      # sumsq | perf_opt (Performance-Optimized)
    peer_w: float = 0.0             # Hinton's peer-normalization weight
    kernel_impl: str = "auto"       # ops.FF_DENSE_IMPLS — "auto" plus the
    #                                 kernel impl registry's names
    #                                 (kernels.registry; validated by
    #                                 api.fit). "auto" consults the
    #                                 autotuner's tuning table first.
    seed: int = 0


PAPER_MLP = FFMLPConfig()

# CIFAR-10 variant (paper §5.6): 32*32*3 inputs, same hidden stack.
PAPER_MLP_CIFAR = dataclasses.replace(PAPER_MLP, layer_sizes=(3072, 2000, 2000, 2000, 2000))
