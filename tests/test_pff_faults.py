"""Executor resilience tests: deterministic fault injection,
chapter-granular checkpoint/resume, retry/backoff with dead-node
degradation, and elastic federated membership.

Like tests/test_pff_exec.py, the multi-device kill-then-resume run
happens in subprocesses (conftest keeps the in-process runner on one
CPU device); everything else exercises the multi-NODE logic in-process
by handing the executor the same device N times — node identity, the
hand-off slots, fault injection and the retry machinery are all
per-logical-node, so one physical device covers them.
"""
import os
import subprocess
import sys

import jax
import pytest

from repro import api, data as data_lib
from repro.configs.ff_mlp import FFMLPConfig
from repro.core import faults, pff, pff_dag, pff_exec

_SRC = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                    os.pardir, "src")


def _cfg(splits=3, sizes=(784, 32, 32), **kw):
    base = dict(layer_sizes=sizes, epochs=splits * 2, splits=splits,
                neg_mode="random", classifier="goodness",
                goodness_fn="sumsq", batch_size=64, seed=0)
    base.update(kw)
    return FFMLPConfig(**base)


@pytest.fixture(scope="module")
def task():
    return data_lib.mnist_like(n_train=260, n_test=100)


def _exec_fit(cfg, task, nodes=3, schedule="all_layers", **kw):
    d0 = jax.devices()[0]
    return api.fit(cfg, task, backend="executor", schedule=schedule,
                   num_nodes=nodes, devices=[d0] * nodes, **kw)


# ---------------------------------------------------------------------------
# FaultPlan semantics (pure data — no executor)
# ---------------------------------------------------------------------------

def test_fault_plan_matching_and_budget():
    plan = faults.FaultPlan([
        faults.Fault("crash", task="train", layer=0, chapter=1, times=2),
        faults.Fault("delay", node=1, delay_ms=50.0, times=-1),
    ])
    # wildcards: node is unspecified -> matches any node; budget of 2
    assert plan.should_crash("train", 0, 1, 3)
    assert plan.should_crash("train", 0, 1, 0)
    assert not plan.should_crash("train", 0, 1, 0)   # budget exhausted
    assert not plan.should_crash("train", 1, 1, 0)   # wrong layer
    assert plan.delay_s("head", 2, 0, 1) == pytest.approx(0.05)
    assert plan.delay_s("head", 2, 0, 0) == 0.0      # wrong node
    assert plan.fired == {"crash": 2, "delay": 1}
    plan.reset()
    assert plan.fired == {} and plan.should_crash("train", 0, 1, 9)


def test_fault_plan_handoff_and_kill():
    plan = faults.FaultPlan([
        faults.Fault("drop_handoff", task="state", layer=1, times=1),
        faults.Fault("corrupt_handoff", node=2, times=-1),
        faults.Fault("kill", chapter=2, phase="post", times=1),
    ])
    assert plan.handoff_action(("state", 1), 0, 0) == "drop"
    assert plan.handoff_action(("state", 1), 0, 1) is None  # budget spent
    assert plan.handoff_action(("params", 0), 2, 5) == "corrupt"
    assert not plan.kill_now(2, "mid")     # wrong phase
    assert not plan.kill_now(1, "post")    # wrong chapter
    assert plan.kill_now(2, "post")
    assert not plan.kill_now(2, "post")    # budget spent


def test_fault_plan_json_roundtrip_and_validation():
    plan = faults.FaultPlan([faults.Fault("crash", node=1, times=-1)],
                            seed=7)
    clone = faults.FaultPlan.from_json(plan.to_json())
    assert clone.seed == 7 and clone.faults == plan.faults
    with pytest.raises(ValueError, match="unknown fault kind"):
        faults.Fault("meteor")
    with pytest.raises(ValueError, match="phase"):
        faults.Fault("kill", phase="pre")
    with pytest.raises(KeyError, match="unknown fault plan"):
        faults.named_plan("nope", splits=2, n_layers=2, num_nodes=2)


# ---------------------------------------------------------------------------
# Replay frontier (the DAG property checkpoint/resume rests on)
# ---------------------------------------------------------------------------

def test_replay_frontier_is_closed_cut():
    frontier = pff_dag.replay_frontier(3, 4, 2, has_head=True,
                                       has_neg=True, strict_neg=True)
    assert all(t.chapter >= 2 for t in frontier)
    kinds = {(t.kind, t.chapter) for t in frontier}
    assert ("train", 2) in kinds and ("head", 3) in kinds
    # chapter 0 and splits are the trivial cuts
    assert len(pff_dag.replay_frontier(3, 4, 0)) == 12
    assert pff_dag.replay_frontier(3, 4, 4) == []
    with pytest.raises(ValueError, match="outside"):
        pff_dag.replay_frontier(3, 4, 5)


# ---------------------------------------------------------------------------
# _Handoff: version skew + injected drop/corrupt
# ---------------------------------------------------------------------------

def test_handoff_version_skew_falls_through_to_fresh_pull():
    """A slot parked at an older producing chapter must NOT satisfy a
    consumer that needs a newer version — take() falls back to pulling
    the live tree (the bit-exactness guarantee of the double buffer)."""
    import jax.numpy as jnp

    d0 = jax.devices()[0]
    h = pff_exec._Handoff([d0, d0], enabled=True)
    stale = {"w": jnp.zeros((2,))}
    live = {"w": jnp.ones((2,))}
    h.prefetch(("state", 0), 1, 0, stale)        # version 0 parked
    got = h.take(("state", 0), 1, 1, live)       # version 1 wanted
    assert bool(jnp.array_equal(got["w"], live["w"]))
    assert h.stats["prefetch_hits"] == 0
    assert h.stats["pulls_local"] == 1
    # matching version serves the parked copy (and pops when asked)
    got = h.take(("state", 0), 1, 0, live, pop=True)
    assert bool(jnp.array_equal(got["w"], stale["w"]))
    assert ("state", 0, 1) not in h.slots and h.stats["prefetch_hits"] == 1


def test_handoff_drop_and_corrupt_injection():
    import jax.numpy as jnp

    d0 = jax.devices()[0]
    plan = faults.FaultPlan([
        faults.Fault("drop_handoff", task="state", times=1),
        faults.Fault("corrupt_handoff", task="params", times=1),
    ])
    h = pff_exec._Handoff([d0, d0], enabled=True,
                          fault_cb=plan.handoff_action)
    live = {"w": jnp.ones((2,))}
    h.prefetch(("state", 0), 1, 0, live)         # dropped
    assert h.stats["prefetch_dropped"] == 1 and not h.slots
    h.prefetch(("params", 0), 1, 0, live)        # poisoned + flagged
    assert h.stats["corrupt_injected"] == 1
    got = h.take(("params", 0), 1, 0, live)      # detected, re-pulled
    assert h.stats["corrupt_detected"] == 1
    assert bool(jnp.all(jnp.isfinite(got["w"])))


# ---------------------------------------------------------------------------
# Executor-level: retry, dead node, checkpoint/resume, elastic — all on
# one physical device standing in for N logical nodes
# ---------------------------------------------------------------------------

def test_crash_retry_is_bit_exact_and_counted(task):
    cfg = _cfg()
    ref = _exec_fit(cfg, task)
    plan = faults.named_plan("crash_once", splits=cfg.splits, n_layers=2,
                             num_nodes=3)
    rc = faults.ResilienceConfig(fault_plan=plan, backoff_base_s=0.001)
    res = _exec_fit(cfg, task, resilience=rc)
    assert pff_exec.params_bit_equal(ref.params, res.params)
    assert res.resilience["retries"] == 1
    assert res.resilience["faults_injected"] == {"crash": 1}
    assert res.resilience["dead_nodes"] == []


def test_dead_node_reassignment_is_bit_exact(task):
    cfg = _cfg()
    ref = _exec_fit(cfg, task)
    plan = faults.named_plan("dead_node", splits=cfg.splits, n_layers=2,
                             num_nodes=3)
    rc = faults.ResilienceConfig(fault_plan=plan, max_retries=1,
                                 backoff_base_s=0.001)
    res = _exec_fit(cfg, task, resilience=rc)
    assert pff_exec.params_bit_equal(ref.params, res.params)
    st = res.resilience
    assert st["dead_nodes"] == [2] and st["reassignments"] == 1
    assert st["retries"] >= 1


def test_federated_dead_node_drops_shard_gracefully(task):
    cfg = _cfg()
    plan = faults.named_plan("dead_node", splits=cfg.splits, n_layers=2,
                             num_nodes=3)
    rc = faults.ResilienceConfig(fault_plan=plan, max_retries=1,
                                 backoff_base_s=0.001)
    res = _exec_fit(cfg, task, schedule="federated", resilience=rc)
    st = res.resilience
    assert st["shards_dropped"] == 1
    # node 2 owned exactly one of the 3 chapters
    assert st["chapters_skipped"] == 1 and st["reassignments"] == 0
    assert 0.0 <= res.test_acc <= 1.0


def test_checkpoint_resume_bit_exact(task, tmp_path):
    cfg = _cfg()
    ref = _exec_fit(cfg, task)
    rc = faults.ResilienceConfig(checkpoint_dir=str(tmp_path),
                                 keep_last=2)
    full = _exec_fit(cfg, task, resilience=rc)
    assert pff_exec.params_bit_equal(ref.params, full.params)
    st = full.resilience
    assert st["checkpoints_written"] == cfg.splits
    # retention pruned to keep_last
    names = sorted(os.listdir(tmp_path))
    assert names == ["pff_chapter_0001.npz", "pff_chapter_0002.npz"]
    # resume from the OLDER manifest -> replays the last chapter and
    # lands on the identical weight stream
    res = _exec_fit(cfg, task,
                    resume_from=str(tmp_path / "pff_chapter_0001.npz"))
    assert pff_exec.params_bit_equal(ref.params, res.params)
    assert res.resilience["resumed_from_chapter"] == 1
    # resume from the directory picks the newest
    res = _exec_fit(cfg, task, resume_from=str(tmp_path))
    assert res.resilience["resumed_from_chapter"] == 2
    assert pff_exec.params_bit_equal(ref.params, res.params)


def test_checkpoint_resume_with_head_and_adaptive_neg(task, tmp_path):
    """The recovery line must carry the published negatives and the
    softmax head too (score-needing strategy + trained head)."""
    cfg = _cfg(neg_mode="adaptive", classifier="softmax")
    ref = _exec_fit(cfg, task)
    rc = faults.ResilienceConfig(checkpoint_dir=str(tmp_path))
    _exec_fit(cfg, task, resilience=rc)
    res = _exec_fit(cfg, task,
                    resume_from=str(tmp_path / "pff_chapter_0001.npz"))
    assert pff_exec.params_bit_equal(ref.params, res.params,
                                     with_head=True)


def test_resume_rejects_mismatched_run(task, tmp_path):
    cfg = _cfg()
    rc = faults.ResilienceConfig(checkpoint_dir=str(tmp_path))
    _exec_fit(cfg, task, resilience=rc)
    other = _cfg(seed=1)
    with pytest.raises(ValueError, match="different run"):
        _exec_fit(other, task, resume_from=str(tmp_path))
    with pytest.raises(FileNotFoundError):
        _exec_fit(cfg, task, resume_from=str(tmp_path / "empty"))


def test_elastic_federated_matches_sequential_reference(task):
    cfg = _cfg()
    member = {0: [0, 1], 1: [0, 1, 2], 2: [1, 2]}.__getitem__
    ref = pff.run_elastic_federated(cfg, task, 3, member)
    rc = faults.ResilienceConfig(membership=member)
    res = _exec_fit(cfg, task, schedule="federated", resilience=rc)
    assert pff_exec.params_bit_equal(ref.params, res.params)
    rounds = res.resilience["elastic_rounds"]
    assert [r["live"] for r in rounds] == [[0, 1], [0, 1, 2], [1, 2]]
    assert all(abs(sum(r["weights"]) - 1.0) < 1e-9 for r in rounds)


def test_elastic_membership_validation(task):
    cfg = _cfg()
    with pytest.raises(ValueError, match="Federated"):
        _exec_fit(cfg, task, schedule="all_layers",
                  resilience=faults.ResilienceConfig(
                      membership=lambda r: [0]))
    with pytest.raises(ValueError, match="key-only"):
        _exec_fit(_cfg(neg_mode="adaptive"), task, schedule="federated",
                  resilience=faults.ResilienceConfig(
                      membership=lambda r: [0]))
    rc = faults.ResilienceConfig(membership=lambda r: [])
    with pytest.raises(ValueError, match="no live nodes"):
        _exec_fit(cfg, task, schedule="federated", resilience=rc)


def test_resilience_rejected_on_non_executor_backends(task):
    cfg = _cfg()
    with pytest.raises(ValueError, match="executor-backend"):
        api.fit(cfg, task, backend="sequential",
                resilience=faults.ResilienceConfig())
    with pytest.raises(ValueError, match="executor-backend"):
        api.fit(cfg, task, backend="federated", resume_from="/tmp/x")


# ---------------------------------------------------------------------------
# Kill-then-resume, 4 real (faked) devices, in a subprocess pair
# ---------------------------------------------------------------------------

def test_kill_then_resume_bit_exact_subprocess(tmp_path):
    """Hard-kill (os._exit) the executor mid-chapter, then resume from
    the surviving manifests; the resumed CLI gates its weight stream
    bit-exact against the fault-free sequential trainer and exits
    non-zero on divergence."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    base = [sys.executable, "-m", "repro.core.pff_exec",
            "--schedule", "all_layers", "--nodes", "4", "--splits", "4",
            "--n-train", "260"]
    td = str(tmp_path)
    killed = subprocess.run(
        base + ["--fault-plan", "kill_mid", "--checkpoint-dir", td],
        capture_output=True, text=True, env=env, timeout=540)
    assert killed.returncode == faults.KILL_EXIT, (
        f"stdout:\n{killed.stdout}\nstderr:\n{killed.stderr}")
    assert any(f.startswith("pff_chapter_") for f in os.listdir(td))
    resumed = subprocess.run(
        base + ["--resume-from", td], capture_output=True, text=True,
        env=env, timeout=540)
    assert resumed.returncode == 0, (
        f"stdout:\n{resumed.stdout}\nstderr:\n{resumed.stderr}")
    assert "bit-exact vs fault-free reference" in resumed.stdout
