"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.ff_dense import ff_dense
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba2_ssd import mamba2_ssd


@pytest.mark.parametrize("M,K,N", [(64, 784, 512), (128, 256, 2000),
                                   (100, 333, 257), (16, 64, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ff_dense(M, K, N, dtype, key):
    x = jax.random.normal(key, (M, K), dtype)
    w = (jax.random.normal(key, (K, N), jnp.float32) * K ** -0.5).astype(
        dtype)
    b = jnp.zeros((N,), dtype)
    y, g = ff_dense(x, w, b)
    yr, gr = ref.ff_dense_ref(x, w, b)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(g, gr, rtol=5 * tol, atol=5 * tol)


@pytest.mark.parametrize("B,S,H,KV,hd", [(2, 256, 4, 2, 64),
                                         (1, 128, 8, 1, 32),
                                         (2, 128, 4, 4, 128)])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 64),
                                           (False, None)])
def test_flash_attention(B, S, H, KV, hd, causal, window, key):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    o = flash_attention(q, k, v, causal=causal, window=window,
                        bq=64, bk=64)
    orf = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(o, orf, rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16(key):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 64), jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 128, 2, 64), jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 128, 2, 64), jnp.bfloat16)
    o = flash_attention(q, k, v, bq=64, bk=64)
    orf = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(orf, np.float32),
                               rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("B,S,H,hd,N,chunk", [(2, 128, 4, 32, 16, 32),
                                              (1, 256, 8, 16, 64, 64),
                                              (2, 64, 2, 64, 128, 64)])
def test_mamba2_ssd(B, S, H, hd, N, chunk, key):
    ks = jax.random.split(key, 4)
    xbar = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    dA = -jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    b = jax.random.normal(ks[2], (B, S, N), jnp.float32)
    c = jax.random.normal(ks[3], (B, S, N), jnp.float32)
    y, hT = mamba2_ssd(xbar, dA, b, c, chunk=chunk)
    yr, hTr = ref.mamba2_ssd_ref(xbar, dA, b, c)
    np.testing.assert_allclose(y, yr, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(hT, hTr, rtol=2e-4, atol=2e-4)


def test_ssd_kernel_matches_model_path(key):
    """The Pallas SSD kernel must agree with the model's streaming scan
    (repro.models.ssm.ssd_chunked) — same chunking math, two codepaths."""
    from repro.models.ssm import ssd_chunked
    ks = jax.random.split(key, 4)
    B, S, H, hd, N = 2, 128, 4, 32, 16
    xh = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.2)
    b = jax.random.normal(ks[3], (B, S, N), jnp.float32)
    c = jax.random.normal(ks[0], (B, S, N), jnp.float32)
    y_model, h_model = ssd_chunked(xh, dt, A, b, c, 32)
    xbar = xh * dt[..., None]
    dA = dt * A
    y_kern, h_kern = mamba2_ssd(xbar, dA, b, c, chunk=32)
    np.testing.assert_allclose(y_model, y_kern, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(h_model, h_kern, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# ops dispatch contract: all three ops share the registry-backed impl=
# interface (helpful unknown-impl error, forced ref == direct oracle,
# force_pallas deprecation shim).
# ---------------------------------------------------------------------------

def _op_args(op, key):
    if op == "ff_dense":
        return (jax.random.normal(key, (16, 64)),
                jax.random.normal(key, (64, 128)) * 0.1,
                jnp.zeros((128,)))
    if op == "flash_attention":
        ks = jax.random.split(key, 3)
        return (jax.random.normal(ks[0], (1, 128, 4, 32)),
                jax.random.normal(ks[1], (1, 128, 2, 32)),
                jax.random.normal(ks[2], (1, 128, 2, 32)))
    ks = jax.random.split(key, 4)
    return (jax.random.normal(ks[0], (1, 128, 2, 16)),
            -jax.nn.softplus(jax.random.normal(ks[1], (1, 128, 2))),
            jax.random.normal(ks[2], (1, 128, 16)),
            jax.random.normal(ks[3], (1, 128, 16)))


@pytest.mark.parametrize("op", ["ff_dense", "flash_attention",
                                "mamba2_ssd"])
def test_ops_unknown_impl_lists_choices(op, key):
    fn = getattr(ops, op)
    with pytest.raises(ValueError, match="auto | pallas | ref"):
        fn(*_op_args(op, key), impl="nope")


@pytest.mark.parametrize("op,ref_fn", [
    ("ff_dense", ref.ff_dense_ref),
    ("flash_attention", ref.flash_attention_ref),
    ("mamba2_ssd", ref.mamba2_ssd_ref)])
def test_ops_forced_ref_is_the_oracle(op, ref_fn, key):
    args = _op_args(op, key)
    got = getattr(ops, op)(*args, impl="ref")
    want = ref_fn(*args)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        assert bool(jnp.array_equal(a, b))


@pytest.mark.parametrize("op", ["ff_dense", "flash_attention",
                                "mamba2_ssd"])
def test_ops_force_pallas_warns_and_delegates(op, key):
    """The legacy boolean must warn DeprecationWarning on every op and
    produce the impl='pallas' result."""
    args = _op_args(op, key)
    fn = getattr(ops, op)
    with pytest.warns(DeprecationWarning, match="impl='pallas'"):
        got = fn(*args, force_pallas=True)
    want = fn(*args, impl="pallas")
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        assert bool(jnp.array_equal(a, b))
    # force_pallas=False defers to the impl argument unchanged
    with pytest.warns(DeprecationWarning):
        got = fn(*args, force_pallas=False, impl="ref")
    for a, b in zip(jax.tree.leaves(got),
                    jax.tree.leaves(fn(*args, impl="ref"))):
        assert bool(jnp.array_equal(a, b))


def test_ops_impls_tuples_are_live_registry_views():
    assert ops.FF_DENSE_IMPLS[0] == "auto"
    assert set(ops.FF_DENSE_IMPLS) >= {"auto", "pallas", "ref"}
    assert set(ops.FLASH_ATTENTION_IMPLS) >= {"auto", "pallas", "ref"}
    assert set(ops.MAMBA2_SSD_IMPLS) >= {"auto", "pallas", "ref"}


def test_chunked_attention_matches_ref(key):
    """The model's pure-JAX chunked attention vs the dense oracle."""
    from repro.models.attention import chunked_attention
    ks = jax.random.split(key, 3)
    for causal, window in [(True, None), (True, 32), (False, None)]:
        q = jax.random.normal(ks[0], (2, 128, 4, 32), jnp.float32)
        k = jax.random.normal(ks[1], (2, 128, 2, 32), jnp.float32)
        v = jax.random.normal(ks[2], (2, 128, 2, 32), jnp.float32)
        o = chunked_attention(q, k, v, causal=causal, window=window,
                              q_chunk=32, k_chunk=64)
        orf = ref.flash_attention_ref(q, k, v, causal=causal,
                                      window=window)
        np.testing.assert_allclose(o, orf, rtol=2e-5, atol=2e-5)
