"""mamba2-780m — SSD (state-space duality) [arXiv:2405.21060].

48 blocks, d_model=1536, attention-free, no MLP (d_ff=0), vocab=50280,
ssm_state=128. d_inner = 2*d_model = 3072, head_dim = 64 -> 48 SSD heads.
"""
from repro.configs.base import ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-780m",
    arch_type="ssm",
    num_layers=48,
    d_model=1536,
    n_heads=48,          # SSD heads (d_inner / head_dim)
    n_kv=48,
    d_ff=0,              # attn-free Mamba2: no interleaved MLP
    vocab=50280,
    groups=((("mamba2",), 48),),
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4,
                  chunk=256),
    tie_embeddings=True,
    source="arXiv:2405.21060 (Mamba-2, SSD)",
))
