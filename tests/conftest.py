"""Shared fixtures. NOTE: no XLA_FLAGS here on purpose — tests must see
the real (single) CPU device; only launch/dryrun.py fakes 512 devices."""
import jax
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
