"""Checkpointing: pytree <-> .npz with path-string keys.

Handles the framework's param/optimizer pytrees (nested dicts/tuples of
arrays). Restore requires a template pytree (for structure + dtypes),
which is how the launcher resumes: init abstract params, then load.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or arr.dtype.name == "bfloat16":
            # np.savez can't persist ml_dtypes — upcast (lossless f32)
            arr = np.asarray(jnp.asarray(leaf, jnp.float32))
        flat[key] = arr
    return flat


def save(path, tree, step=None):
    flat = _flatten(tree)
    if step is not None:
        flat["__step__"] = np.asarray(step)
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(tmp, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp, path)


def restore(path, template):
    """Returns (tree_like_template, step or None)."""
    with np.load(path) as z:
        data = {k: z[k] for k in z.files}
    step = data.pop("__step__", None)
    leaves_p = jax.tree_util.tree_flatten_with_path(template)
    paths, treedef = leaves_p[0], leaves_p[1]
    out = []
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if key not in data:
            raise KeyError(f"checkpoint missing {key}")
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        # two-step conversion: numpy can't cast ml_dtypes (bf16) directly
        out.append(jnp.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out), (
        int(step) if step is not None else None)
