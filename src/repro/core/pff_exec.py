"""Real multi-device PFF executor: the paper's schedules on actual devices.

Where ``repro.core.pff`` times the canonical chapter schedule once and
REPLAYS the timings through an event-driven simulator, this module RUNS
the Single-Layer, All-Layers and Federated schedules concurrently across
an actual ``jax.devices()`` set — one device per paper "node"
(``launch.mesh.pff_node_devices``; on CI/CPU export
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` before importing
jax). The chapter-task DAG and the per-schedule node assignments come
from ``repro.core.pff_dag`` — the same module the simulator replays.

Execution model: the per-schedule drivers dispatch tasks in the DAG's
canonical topological order (the same order ``pff_dag.build_tasks``
lists; node assignments come from ``pff_dag.node_of`` & co — the
dependency EDGES are realized implicitly as JAX data dependencies, which
``tests/test_pff_exec.py``'s ``test_dag_topological_order`` plus the
bit-exactness oracle keep honest against the DAG module) and never
block. Every task's inputs are ``jax.device_put`` onto
its owning node (activation/weight hand-off along the DAG edges), the
jitted chapter trainers (``ff_mlp.train_layer_chapter`` & co — the fused
Pallas ``ff_dense`` hot loop, with donated param/opt buffers) are
dispatched asynchronously, and JAX's async runtime overlaps nodes: node
i crunches chapter c while node i+1 already trains layer 0 of chapter
c+1. Makespan is wall-clock from first dispatch to the last weight
buffer becoming ready.

Bit-exactness: the DAG fixes the weight-update order, so the executor
reuses the EXACT eager/jitted call sequence of the sequential trainer
per task — same keys, same learning-rate arrays, same kernel path — and
therefore reproduces ``pff.train_ff_mlp``'s weight stream bit-exactly
for All-Layers (and Federated vs ``pff.train_federated``). That is the
correctness oracle enforced by ``tests/test_pff_exec.py``. AdaptiveNEG
negatives are regenerated with "publish" semantics (the DAG's
``strict_neg`` gating: chapter c+1 trains on negatives from the full
chapter-c model), which is exactly what the sequential trainer does;
RandomNEG negatives depend only on the PRNG key, so each node
regenerates its own locally — parallel, and still bit-exact.

Double-buffered hand-off: with ``overlap=True`` (the default) every
cross-node ``device_put`` along a DAG edge is issued the moment its
producing task has been DISPATCHED, not when its consuming task needs
the data — per-(tree, node) transfer slots (``_Handoff``) so the next
chapter's weights/negatives stream onto their destination node while
the current chapter's compute is still in flight. The prefetch targets
come from ``pff_dag.handoff_targets`` / ``chapter_train_nodes`` — the
same DAG edges the dispatch order walks — and every slot is tagged with
the producing chapter (version): a consumer takes the prefetched copy
only when the version matches the state it would have pulled on demand,
so the overlapped weight stream is the bit-exact SAME weight stream
(``device_put`` moves bits, the version gate proves they are the right
ones; the on/off A-B case in ``tests/test_pff_exec.py`` enforces it).
``overlap=False`` restores the serialize-on-demand hand-off for A/B
measurement.

``benchmarks/pff_exec.py`` records this executor's measured makespan
next to the simulator's prediction (``BENCH_pff_exec.json``), with
overlap on and off, plus the hand-off transfer counts.

All strategy variation (negatives / goodness / classifier) comes from
the ``repro.core.strategies`` registries — the same objects the
sequential trainer consumes — including the Performance-Optimized
goodness path (paper §4.4): its per-layer local-head task is a
per-layer dependent of the train task in the DAG
(``pff_dag.build_tasks(has_local_heads=True)``), owned by the same
node, and the executor dispatches it FUSED with its train task (the
§4.4 objective is one two-layer-deep backprop call), which preserves
the DAG order and the bit-exactness oracle.

Resilience (``faults.ResilienceConfig`` via ``api.fit(...,
resilience=...)``): because the DAG has no backward edges, a completed
chapter is a CONSISTENT recovery line (``pff_dag.replay_frontier``) —
the executor exploits that four ways. (1) chapter-granular
checkpointing: after chapter c an atomic manifest (node states + head +
published negatives + hand-off versions, ``repro.checkpoint`` with
``meta``/``strict``) lands in ``checkpoint_dir``; ``run(resume_from=
...)`` replays from the last completed chapter BIT-EXACTLY — the
kill-then-resume gates in ``tests/test_pff_faults.py`` and
``benchmarks/pff_faults.py`` prove the resumed weight stream equals the
uninterrupted one. (2) retry with exponential backoff: an injected
crash (``faults.FaultPlan`` — deterministic, schedule-addressable)
fires at task ENTRY, before the hand-off take / buffer donation, so a
retry re-dispatches the identical task. (3) graceful degradation: on
budget exhaustion the node is declared dead — all_layers/single_layer
remap its logical node to a surviving device (same math, still
bit-exact); federated rolls the chapter back and drops the dead node's
shard for that round. (4) elastic federated membership: a
``membership(round)`` callback names the live nodes each round; every
live node trains a COPY of the round-start model on its own shard and
the aggregator averages weighted by live shard sizes — bit-checked
against the sequential reference ``pff.run_elastic_federated`` (both
call ``pff.elastic_node_round``). ``ExecResult.resilience`` reports
retries, reassignments, checkpoint/restore cost and faults injected.

Observability (``repro.obs``): ``run(trace=...)`` records one
``task:<kind>`` span per DAG task (attrs kind/layer/chapter/node),
``handoff:*`` events from the transfer slots, ``resilience:*`` events
and counters from the retry/checkpoint machinery, and a closing
``run`` span carrying the DAG shape — everything ``obs.analyze`` needs
to rebuild the critical path over ``pff_dag.deps`` and attribute
hand-off cost on/off it. The old ``profile=True`` path now rides the
tracer: ``ExecResult.records`` / ``node_busy`` are derived from the
task spans (identical order and semantics), and the untraced default
pays only no-op tracer calls (``obs.trace.NOOP``).
"""
from __future__ import annotations

import dataclasses
import glob
import os
import re
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import checkpoint as checkpoint_lib, data as data_lib, optim
from repro.core import faults as faults_lib
from repro.core import ff, ff_mlp, pff, pff_dag, pff_lm, strategies
from repro.launch import mesh as mesh_lib
from repro.models import transformer
from repro.obs import trace as obs_trace


@dataclasses.dataclass
class ExecResult:
    params: dict
    schedule: str
    num_nodes: int
    makespan: float                        # seconds, first dispatch -> ready
    test_acc: Optional[float]              # None for LM runs (use eval CE)
    records: Optional[List[pff.TaskRecord]]  # per-task durations (traced)
    node_busy: Optional[List[float]]         # per-node busy seconds (traced)
    handoff: Optional[dict] = None           # transfer-slot counters
    resilience: Optional[dict] = None        # retry/checkpoint/fault stats
    trace: Optional[object] = None           # obs.trace.Tracer, if traced


def _records_from_spans(tracer, span0, num_nodes):
    """(records, node_busy) derived from the ``task:*`` spans of one
    run — the traced-profile view both executors share (same order and
    blocked durations the old ``profile=True`` path collected, so
    ``pff.simulate_schedule`` replays traced runs unchanged)."""
    records = []
    node_busy = [0.0] * num_nodes
    for s in tracer.snapshot(start=span0):
        if not s.name.startswith("task:"):
            continue
        a = s.attrs
        records.append(pff.TaskRecord(a["kind"], a["layer"],
                                      a["chapter"], s.duration))
        node_busy[a["node"]] += s.duration
    return records, node_busy


class _ShardDropped(Exception):
    """Raised inside a federated chapter when its owning node dies with
    the retry budget exhausted — the run loop rolls the chapter back and
    drops that node's shard for the round (graceful degradation)."""

    def __init__(self, node):
        super().__init__(f"node {node} dead; shard dropped this round")
        self.node = node


def checkpoint_path(directory: str, chapter: int) -> str:
    """Canonical chapter-manifest filename."""
    return os.path.join(directory, f"pff_chapter_{chapter:04d}.npz")


def latest_checkpoint(directory: str) -> Optional[str]:
    """Newest (highest-chapter) manifest in ``directory``, or None."""
    paths = glob.glob(os.path.join(directory, "pff_chapter_*.npz"))
    if not paths:
        return None
    return max(paths, key=lambda p: int(
        re.search(r"pff_chapter_(\d+)\.npz$", p).group(1)))


class _Handoff:
    """Double-buffered transfer slots for the DAG hand-off.

    ``prefetch`` enqueues an async ``device_put`` of a pytree onto its
    future consumer's device and parks it under ``(name, node)`` tagged
    with the producing chapter. ``take`` returns the parked copy iff the
    version matches what the consumer would have pulled on demand —
    otherwise (or with overlap disabled) it falls back to a synchronous-
    path ``device_put`` exactly like the pre-overlap executor. Slots
    whose trees will be DONATED by the consuming jit are popped on hit
    (``pop=True``) so an invalidated buffer can never be re-served;
    params-only slots stay parked so several same-chapter consumers on
    one node share a single transfer.

    Counters (the dispatch-count measurement in ``BENCH_pff_exec.json``):
    ``prefetch_issued``/``prefetch_hits`` and the fallback pulls, split
    into ``pulls_cross`` (a real inter-node transfer on the consumer's
    critical path — what double-buffering exists to hide) vs
    ``pulls_local`` (same-device no-ops).

    Fault hooks (``fault_cb`` — ``faults.FaultPlan.handoff_action``):
    a "drop" fault loses the transfer (the slot is never parked — the
    consumer's on-demand fallback IS the recovery path, so the weight
    stream cannot change); a "corrupt" fault parks the copy with its
    float leaves NaN-poisoned and the slot's integrity flag set,
    modelling a checksum failure on receive. ``take`` deletes a
    corrupt-flagged slot and re-pulls fresh bits instead of serving it
    (``corrupt_detected``); serving the poisoned tree would NaN the
    weights, so a regression here fails the bit-exactness oracle loudly.
    """

    def __init__(self, devices, enabled: bool, fault_cb=None,
                 tracer=obs_trace.NOOP):
        self.devices = devices
        self.enabled = enabled
        self.fault_cb = fault_cb
        self.tracer = tracer
        self.slots: Dict[tuple, tuple] = {}   # (name, node) -> (ver, tree, corrupt)
        self.stats = {"prefetch_issued": 0, "prefetch_hits": 0,
                      "pulls_cross": 0, "pulls_local": 0,
                      "prefetch_dropped": 0, "corrupt_injected": 0,
                      "corrupt_detected": 0}

    def _event(self, name, slot_name, node, version):
        # every counter bump mirrors onto the tracer timeline, so the
        # analyzer's on/off-critical-path attribution reconciles with
        # these stats exactly (a trace-smoke gate)
        if self.tracer.enabled:
            self.tracer.event(name, tree=str(slot_name[0]), node=node,
                              version=version)

    @staticmethod
    def _poison(leaf):
        if jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.floating):
            return jnp.full_like(leaf, jnp.nan)
        return leaf

    def prefetch(self, name, node: int, version: int, tree):
        if not self.enabled:
            return
        corrupt = False
        if self.fault_cb is not None:
            action = self.fault_cb(name, node, version)
            if action == "drop":
                self.stats["prefetch_dropped"] += 1
                self._event("handoff:drop", name, node, version)
                return
            if action == "corrupt":
                tree = jax.tree_util.tree_map(self._poison, tree)
                self.stats["corrupt_injected"] += 1
                corrupt = True
        self.slots[(name, node)] = (
            version, jax.device_put(tree, self.devices[node]), corrupt)
        self.stats["prefetch_issued"] += 1
        self._event("handoff:prefetch_issue", name, node, version)

    def _on_device(self, tree, dev) -> bool:
        leaves = jax.tree_util.tree_leaves(tree)
        try:
            return bool(leaves) and leaves[0].devices() == {dev}
        except AttributeError:                      # non-committed leaf
            return False

    def take(self, name, node: int, version: int, tree, *,
             pop: bool = False):
        slot = self.slots.get((name, node))
        if slot is not None and slot[0] == version:
            if slot[2]:
                # integrity gate: poisoned bits are never served
                del self.slots[(name, node)]
                self.stats["corrupt_detected"] += 1
                self._event("handoff:corrupt_detected", name, node, version)
            else:
                if pop:
                    del self.slots[(name, node)]
                self.stats["prefetch_hits"] += 1
                self._event("handoff:prefetch_hit", name, node, version)
                return slot[1]
        dev = self.devices[node]
        local = self._on_device(tree, dev)
        self.stats["pulls_local" if local else "pulls_cross"] += 1
        self._event("handoff:pull_local" if local else "handoff:pull_cross",
                    name, node, version)
        return jax.device_put(tree, dev)

    def drop_node_slots(self, node: int):
        """Forget parked copies destined for a dead node's device."""
        for key in [k for k in self.slots if k[1] == node]:
            del self.slots[key]


class PFFExecutor:
    """Runs one PFF schedule for real on ``num_nodes`` devices.

    ``run()`` re-initializes params from ``cfg.seed`` every call, so
    calling it twice and timing the second run measures a warm cache
    (all per-device executables compiled) — what the benchmark does.
    """

    def __init__(self, cfg, task: data_lib.ImageTask, schedule: str,
                 num_nodes: int, *, devices=None, overlap: bool = True,
                 resilience: Optional[faults_lib.ResilienceConfig] = None):
        if schedule not in pff_dag.SCHEDULES:
            raise ValueError(f"unknown schedule {schedule!r}; expected "
                             f"one of {pff_dag.SCHEDULES}")
        if schedule == "sequential" and num_nodes != 1:
            raise ValueError("sequential means num_nodes=1")
        self.cfg = cfg
        self.task = task
        self.schedule = schedule
        self.num_nodes = num_nodes
        self.overlap = overlap
        self.devices = (list(devices)[:num_nodes] if devices is not None
                        else mesh_lib.pff_node_devices(num_nodes))
        self._devices_init = list(self.devices)   # undo dead-node remaps
        self.n_layers = len(cfg.layer_sizes) - 1
        self.C = max(cfg.epochs // cfg.splits, 1)
        self.impl = ff_mlp.kernel_impl(cfg)
        self.good = strategies.goodness.get(cfg.goodness_fn)
        self.neg = strategies.negatives.get(cfg.neg_mode)
        self.cls = strategies.classifier.get(cfg.classifier)
        self.has_head = self.cls.trains_head
        self.has_neg = self.good.uses_negatives and self.neg.regenerates
        self.resilience = resilience
        if resilience is not None and resilience.membership is not None:
            if schedule != "federated":
                raise ValueError(
                    "elastic membership is a Federated-PFF mode; got "
                    f"schedule={schedule!r}")
            if self.has_neg and self.neg.needs_scores:
                raise ValueError(
                    f"elastic federated membership supports key-only "
                    f"negative strategies; {cfg.neg_mode!r} needs "
                    f"full-model scores")
        self._tracer = obs_trace.NOOP
        self._block = False
        self._const_dirty = False
        self._setup_constants()

    # ---- per-device constants (replicated once, before any timing) -------
    def _setup_constants(self):
        cfg, task = self.cfg, self.task
        key = jax.random.PRNGKey(cfg.seed)
        self.key = key
        self.kneg = jax.random.fold_in(key, 999)
        shards = None
        if self.schedule == "federated":
            # same shard construction as the sequential federated
            # trainer: chapter c uses shard c % N — which IS node
            # c % N's own shard, so training data never crosses a node
            # boundary.
            shards = pff.federated_shards(cfg, task, self.num_nodes)
        self._const: Dict[int, dict] = {}
        for node, dev in enumerate(self.devices):
            x_d = jax.device_put(task.x_train, dev)
            y_d = jax.device_put(task.y_train, dev)
            c = {"x": x_d, "y": y_d,
                 "idx": (jax.device_put(shards[node], dev)
                         if shards is not None else None)}
            if self.good.uses_negatives:
                c["xp0"] = ff_mlp._norm(ff.overlay_label(
                    x_d, y_d, cfg.num_classes))
                c["xn0_init"] = ff_mlp._norm(self.neg.fn(
                    self.kneg, cfg, None, x_d, y_d, None))
            else:
                c["xk0"] = ff_mlp._norm(ff.overlay_neutral(
                    x_d, cfg.num_classes))
            if self.has_head:
                c["x_neutral"] = ff.overlay_neutral(x_d, cfg.num_classes)
            self._const[node] = c
        jax.block_until_ready([v for c in self._const.values()
                               for v in c.values() if v is not None])

    # ---- helpers ---------------------------------------------------------
    def _lrs(self, chapter):
        cfg, C = self.cfg, self.C
        lrs = jnp.asarray([
            optim.cooldown_lr(cfg.lr_ff, chapter * C + e, cfg.epochs,
                              cfg.cooldown_after) for e in range(C)],
            jnp.float32)
        return lrs, lrs * (cfg.lr_softmax / cfg.lr_ff)

    def _pull(self, tree, node):
        """Async hand-off of a param/opt pytree onto ``node``'s device."""
        return jax.device_put(tree, self.devices[node])

    def _layer_params(self, k, node):
        """Layer k's current params resident on ``node`` — prefetched by
        the producing train task when the DAG says this node consumes
        them, on-demand ``device_put`` otherwise."""
        return self._handoff.take(("params", k), node, self._ver[k],
                                  self._states[k][0])

    def _prefetch_state(self, k, chapter, state):
        """Publish train(k, chapter)'s output toward its DAG consumers
        while the producing node is still crunching (double-buffering)."""
        nxt, param_nodes = pff_dag.handoff_targets(
            self.schedule, self.num_nodes, n_layers=self.n_layers,
            splits=self.cfg.splits, layer=k, chapter=chapter,
            has_head=self.has_head,
            has_neg=self.has_neg and self.neg.needs_scores)
        if nxt is not None:
            self._handoff.prefetch(("state", k), nxt, chapter, state)
        for node in param_nodes:
            self._handoff.prefetch(("params", k), node, chapter, state[0])

    def _fwd(self, lp, x):
        """One layer forward + Hinton length-norm — the inter-layer
        hand-off. ``ff_mlp.fwd_norm`` is the exact call the sequential
        trainer makes (bit-exactness depends on it); the norm divide
        runs in the ``ff_dense`` kernel epilogue."""
        return ff_mlp.fwd_norm(lp, x, impl=self.impl)

    def _xn0_for(self, chapter, node):
        """The (full-size, normalized) negatives the sequential trainer
        would use for this chapter, resident on ``node``."""
        const = self._const[node]
        if not self.has_neg or chapter == 0:
            return const["xn0_init"]
        if not self.neg.needs_scores:
            # key-only — each node regenerates its own copy locally
            # (the paper's parallel per-node UpdateXNEG), bit-identical
            # to the sequential trainer's stream by PRNG determinism.
            return ff_mlp._norm(self.neg.fn(
                jax.random.fold_in(self.kneg, chapter - 1), self.cfg,
                None, const["x"], const["y"], None))
        # score-needing (AdaptiveNEG): published by chapter-(c-1)'s
        # neg_gen task (and prefetched to this node while chapter c-1
        # was still computing, when overlap is on)
        src_chapter, xn0 = self._neg
        assert src_chapter == chapter - 1, (src_chapter, chapter)
        return self._handoff.take(("neg",), node, src_chapter, xn0)

    def _chapter_inputs(self, chapter, node):
        """(acts, extras) exactly as the sequential trainer builds them:
        activations flow layer-to-layer, extras (labels) do not."""
        const = self._const[node]
        idx = const["idx"]
        if self.good.uses_negatives:
            xn0 = self._xn0_for(chapter, node)
            return ((const["xp0"] if idx is None else const["xp0"][idx],
                     xn0 if idx is None else xn0[idx]), ())
        return ((const["xk0"] if idx is None else const["xk0"][idx],),
                (const["y"] if idx is None else const["y"][idx],))

    def _finish_task(self, node, kind, layer, chapter, t0, out):
        """Close one DAG task: block (timeline mode only — real device
        time at the cost of overlap) and record the ``task:<kind>`` span
        the analyzer's critical path is built from. ``ExecResult.records``
        / ``node_busy`` are derived from these spans after the run."""
        if self._block:
            jax.block_until_ready(out)
        tr = self._tracer
        if tr.enabled:
            tr.add_span("task:" + kind, t0, kind=kind, layer=layer,
                        chapter=chapter, node=node)

    def _rtime(self, name, dt):
        """Resilience seconds: one mechanism feeds both ``_rstats``
        (surfaced via ``FitResult.resilience``) and the tracer's
        counters — the scattered ad-hoc timers folded onto the trace."""
        self._rstats[name] += dt
        self._tracer.counter(name, dt)

    # ---- resilience: fault consult, retry/backoff, death, checkpoints ----
    @property
    def _fault_plan(self):
        return (self.resilience.fault_plan
                if self.resilience is not None else None)

    def _resilient(self, kind, layer, chapter, node, body):
        """Run one task body under the resilience policy.

        Injected crashes fire at task ENTRY — before the hand-off take
        and therefore before any buffer donation or state mutation — so
        a retry re-dispatches the IDENTICAL task and the weight stream
        stays bit-exact. Only ``faults.InjectedFault`` is caught; real
        errors still propagate. On budget exhaustion the node is
        declared dead and the loop continues: the crash check skips dead
        nodes, so the body then runs on the reassigned device
        (all_layers / single_layer) — federated instead aborts the
        chapter via ``_ShardDropped`` (the caller rolls it back).
        """
        rc, plan = self.resilience, self._fault_plan
        if plan is not None and node not in self._dead:
            d = plan.delay_s(kind, layer, chapter, node)
            if d > 0:
                time.sleep(d)
        attempt = 0
        while True:
            try:
                if (plan is not None and node not in self._dead
                        and plan.should_crash(kind, layer, chapter, node)):
                    raise faults_lib.InjectedFault(
                        f"injected crash: {kind}(layer={layer}, "
                        f"chapter={chapter}) on node {node}")
                return body()
            except faults_lib.InjectedFault:
                t0 = time.perf_counter()
                if attempt < rc.max_retries:
                    time.sleep(rc.backoff_base_s
                               * rc.backoff_factor ** attempt)
                    attempt += 1
                    self._rstats["retries"] += 1
                    if self._tracer.enabled:
                        self._tracer.event(
                            "resilience:retry", kind=kind, layer=layer,
                            chapter=chapter, node=node, attempt=attempt)
                    self._rtime("recovery_time_s",
                                time.perf_counter() - t0)
                    continue
                self._declare_dead(node)
                self._rtime("recovery_time_s", time.perf_counter() - t0)
                if self.schedule == "federated":
                    raise _ShardDropped(node) from None

    def _declare_dead(self, node):
        """Retry budget exhausted: graceful degradation.

        all_layers / single_layer: remap the LOGICAL node to a surviving
        device (``self.devices`` is mutated in place — the hand-off
        shares the list) and re-place its replicated constants; the
        DAG's node assignments are untouched, so the same tasks run the
        same math on the new device — still bit-exact. What this models
        is scheduling-level task reassignment (state travels through
        the same hand-off/pull path as before). federated: no remap —
        the dead node's shard simply stops contributing.
        """
        self._dead.add(node)
        self._rstats["dead_nodes"].append(node)
        if self._tracer.enabled:
            self._tracer.event("resilience:dead_node", node=node,
                               schedule=self.schedule)
        if self.schedule == "federated":
            self._rstats["shards_dropped"] += 1
            return
        live = [n for n in range(self.num_nodes) if n not in self._dead]
        if not live:
            raise RuntimeError("resilience: every node is dead")
        new_dev = self.devices[live[0]]
        self.devices[node] = new_dev
        self._const[node] = jax.device_put(self._const[node], new_dev)
        self._handoff.drop_node_slots(node)
        self._const_dirty = True
        self._rstats["reassignments"] += 1

    def _maybe_kill(self, chapter, phase):
        plan = self._fault_plan
        if plan is not None and plan.kill_now(chapter, phase):
            # a HARD kill — no cleanup, no atexit — so the kill-then-
            # resume tests exercise real crash recovery. Sync first so
            # the pre-kill checkpoint (phase="post") is really on disk.
            jax.block_until_ready([s[0] for s in self._states])
            if self._tracer.enabled:
                self._tracer.event("resilience:kill", chapter=chapter,
                                   phase=phase)
            print(f"[pff_exec] injected kill at chapter {chapter} "
                  f"({phase})", flush=True)
            os._exit(faults_lib.KILL_EXIT)

    # ---- chapter-granular checkpoint / resume ----------------------------
    def _ckpt_has_neg(self):
        """Published negatives are part of the recovery line only for
        score-needing strategies — key-only ones regenerate from the
        PRNG, so persisting them would be dead weight."""
        return self.has_neg and self.neg.needs_scores

    def _ckpt_template(self):
        """Restore template derived purely from the config — a resumed
        process can rebuild it without reading the manifest first."""
        cfg = self.cfg
        params = ff_mlp.init(jax.random.PRNGKey(cfg.seed), cfg)
        opt = ff_mlp.opt_init(params)
        template = {"states": [self.good.get_state(params, opt, k)
                               for k in range(self.n_layers)],
                    "head": (params["head"], opt["head"])}
        if self._ckpt_has_neg():
            x = jnp.asarray(self.task.x_train)
            template["neg"] = jax.ShapeDtypeStruct(x.shape, jnp.float32)
        return template

    def _write_checkpoint(self, chapter):
        rc = self.resilience
        if rc is None or rc.checkpoint_dir is None:
            return
        last = chapter == self.cfg.splits - 1
        if (chapter + 1) % rc.checkpoint_every != 0 and not last:
            return
        cfg = self.cfg
        t0 = time.perf_counter()
        tree = {"states": list(self._states), "head": self._head}
        if self._ckpt_has_neg():
            tree["neg"] = self._neg[1]
        meta = {"chapter": chapter, "schedule": self.schedule,
                "num_nodes": self.num_nodes, "splits": cfg.splits,
                "seed": cfg.seed, "goodness_fn": cfg.goodness_fn,
                "neg_mode": cfg.neg_mode, "classifier": cfg.classifier,
                "layer_sizes": list(cfg.layer_sizes),
                "neg_chapter": self._neg[0],
                "ver": [int(v) for v in self._ver],
                "head_ver": int(self._head_ver)}
        # checkpoint.save syncs leaves to host — that device->host drain
        # is the per-chapter overhead BENCH_pff_faults.json measures
        checkpoint_lib.save(checkpoint_path(rc.checkpoint_dir, chapter),
                            tree, step=chapter, meta=meta,
                            tracer=self._tracer)
        kept = sorted(glob.glob(os.path.join(rc.checkpoint_dir,
                                             "pff_chapter_*.npz")))
        for old in kept[:-rc.keep_last] if rc.keep_last > 0 else []:
            os.remove(old)
        self._rstats["checkpoints_written"] += 1
        self._rtime("checkpoint_time_s", time.perf_counter() - t0)

    def _restore(self, resume_from):
        """Load a chapter manifest and return its completed chapter."""
        path = resume_from
        if os.path.isdir(path):
            path = latest_checkpoint(path)
            if path is None:
                raise FileNotFoundError(
                    f"no pff_chapter_*.npz manifest in {resume_from!r}")
        cfg = self.cfg
        tree, _, meta = checkpoint_lib.restore(
            path, self._ckpt_template(), strict=True, with_meta=True,
            tracer=self._tracer)
        if meta is None:
            raise ValueError(f"{path!r} carries no manifest meta — not a "
                             f"PFF chapter checkpoint")
        want = {"schedule": self.schedule, "num_nodes": self.num_nodes,
                "splits": cfg.splits, "seed": cfg.seed,
                "goodness_fn": cfg.goodness_fn, "neg_mode": cfg.neg_mode,
                "classifier": cfg.classifier,
                "layer_sizes": list(cfg.layer_sizes)}
        for k, v in want.items():
            if meta.get(k) != v:
                raise ValueError(
                    f"checkpoint {path!r} was written by a different run: "
                    f"{k}={meta.get(k)!r} != {v!r}")
        self._states = list(tree["states"])
        self._head = tree["head"]
        if self._ckpt_has_neg():
            self._neg = (int(meta["neg_chapter"]), tree["neg"])
        self._ver = [int(v) for v in meta["ver"]]
        self._head_ver = int(meta["head_ver"])
        return int(meta["chapter"])

    # ---- per-task bodies (each mirrors the sequential trainer) -----------
    def _train_task(self, k, chapter, node, acts, extras, lrs, kc):
        if self.resilience is None:
            return self._train_task_body(k, chapter, node, acts, extras,
                                         lrs, kc)
        out = self._resilient(
            "train", k, chapter, node,
            lambda: self._train_task_body(k, chapter, node, acts, extras,
                                          lrs, kc))
        if k == 0:
            # "mid-chapter" kill point: the chapter's first train task
            # has completed but the chapter has not — resume must replay
            # the partially-executed chapter from the previous manifest
            self._maybe_kill(chapter, "mid")
        return out

    def _head_task(self, chapter, node, idx, lrs_head, kc):
        if self.resilience is None:
            return self._head_task_body(chapter, node, idx, lrs_head, kc)
        return self._resilient(
            "head", self.n_layers, chapter, node,
            lambda: self._head_task_body(chapter, node, idx, lrs_head,
                                         kc))

    def _neg_task(self, chapter, node):
        if self.resilience is None:
            return self._neg_task_body(chapter, node)
        return self._resilient(
            "neg_gen", -1, chapter, node,
            lambda: self._neg_task_body(chapter, node))

    def _train_task_body(self, k, chapter, node, acts, extras, lrs, kc):
        """One chapter-train task via the goodness strategy. For
        Performance-Optimized goodness this call carries the layer's
        local_head task fused in (see module docstring); it records as
        ONE train task — exactly like the sequential trainer's timing.
        The incoming state was prefetched onto ``node`` while the
        previous chapter computed (popped: the jit donates its buffers);
        the outgoing state is immediately published toward its DAG
        consumers."""
        t0 = self._tracer.now()
        if self.resilience is not None:
            # the driver computed acts/extras before this (possibly
            # retried) dispatch — if the node was reassigned to a
            # surviving device mid-retry they still live on the dead
            # one, so re-place them (same-device no-op otherwise)
            acts = jax.device_put(acts, self.devices[node])
            extras = jax.device_put(extras, self.devices[node])
        state = self._handoff.take(("state", k), node, self._ver[k],
                                   self._states[k], pop=True)
        state = self.good.train_chapter(
            state, acts, extras, lrs, jax.random.fold_in(kc, k),
            cfg=self.cfg, epochs=self.C)
        self._states[k] = state
        self._ver[k] = chapter
        self._prefetch_state(k, chapter, state)
        if self._publish is not None:
            # push the freshly-trained layer onto the serving bus the
            # moment its chapter-train task completes — FF's layer
            # locality is what makes the mid-run per-layer hot-swap
            # sound (no global backward pass to invalidate it). The bus
            # copies before parking; the donated buffers stay ours.
            self._publish.publish_layer(k, chapter, self.good.export([state]))
        self._finish_task(node, "train", k, chapter, t0, state[0])
        return state[0]

    def _head_task_body(self, chapter, node, idx, lrs_head, kc):
        const = self._const[node]
        t0 = self._tracer.now()
        xn_all = (const["x_neutral"] if idx is None
                  else const["x_neutral"][idx])
        # pull every layer onto the head node (no-op when already there,
        # e.g. all_layers; prefetched hand-off for single_layer)
        feats = ff_mlp.softmax_feats(
            [self._layer_params(k, node)
             for k in range(self.n_layers)], xn_all, impl=self.impl)
        head, op = self._handoff.take(("head",), node, self._head_ver,
                                      self._head, pop=True)
        head, op = ff_mlp.train_head_chapter(
            head, op, feats, const["y"] if idx is None else const["y"][idx],
            lrs_head, jax.random.fold_in(kc, 77),
            batch=self.cfg.batch_size, epochs=self.C)
        self._head = (head, op)
        self._head_ver = chapter
        if self._publish is not None:
            self._publish.publish_head(chapter, head)
        if chapter + 1 < self.cfg.splits:
            nxt = pff_dag.head_node_of(self.schedule, self.num_nodes,
                                       n_layers=self.n_layers,
                                       chapter=chapter + 1)
            if nxt != node:
                self._handoff.prefetch(("head",), nxt, chapter,
                                       (head, op))
        self._finish_task(node, "head", self.n_layers, chapter, t0,
                          head["w"])

    def _neg_task_body(self, chapter, node):
        """Score-needing (AdaptiveNEG) regeneration from the full
        chapter-c model, published for the next chapter
        ("UpdateXNEG(publish=True)" — the DAG's strict_neg gating,
        matching the sequential trainer)."""
        const = self._const[node]
        t0 = self._tracer.now()
        params = {"layers": [self._layer_params(k, node)
                             for k in range(self.n_layers)]}
        scores = pff._class_scores_chunked(params, const["x"], self.cfg)
        xn0 = ff_mlp._norm(self.neg.fn(
            jax.random.fold_in(self.kneg, chapter), self.cfg, params,
            const["x"], const["y"], scores))
        self._neg = (chapter, xn0)
        # publish toward every node that trains chapter c+1 while the
        # current chapter's tail (head task etc.) is still in flight
        if chapter + 1 < self.cfg.splits:
            for nxt in pff_dag.chapter_train_nodes(
                    self.schedule, self.num_nodes, self.n_layers,
                    chapter=chapter + 1):
                if nxt != node:
                    self._handoff.prefetch(("neg",), nxt, chapter, xn0)
        self._finish_task(node, "neg_gen", -1, chapter, t0, xn0)

    # ---- schedule drivers ------------------------------------------------
    def _run_chapter_owned(self, chapter):
        """all_layers / federated / sequential: one node runs the whole
        chapter, computing its own forward features as it trains."""
        node = pff_dag.node_of(self.schedule, self.num_nodes, layer=0,
                               chapter=chapter)
        idx = self._const[node]["idx"]
        lrs, lrs_head = self._lrs(chapter)
        kc = jax.random.fold_in(self.key, chapter)
        acts, extras = self._chapter_inputs(chapter, node)
        for k in range(self.n_layers):
            lp = self._train_task(k, chapter, node, acts, extras, lrs,
                                  kc)
            if k + 1 < self.n_layers:
                if self.resilience is not None:
                    # a mid-chapter reassignment leaves this loop's acts
                    # on the dead node's device while lp lands on the
                    # surviving one — re-place (same-device no-op)
                    acts = jax.device_put(acts, self.devices[node])
                acts = tuple(self._fwd(lp, a) for a in acts)
        if self.has_head:
            self._head_task(chapter, node, idx, lrs_head, kc)
        if self.has_neg and self.neg.needs_scores:
            self._neg_task(chapter, node)

    def _run_chapter_single_layer(self, chapter):
        """single_layer: node k owns layer k and re-runs the forward
        pass of layers < k over the train set (Algorithm 1 lines 3-5) —
        the load imbalance the paper observes. Weight hand-off: node k
        pulls layers 0..k-1's chapter-c weights as they appear."""
        lrs, lrs_head = self._lrs(chapter)
        kc = jax.random.fold_in(self.key, chapter)
        for k in range(self.n_layers):
            node = pff_dag.node_of(self.schedule, self.num_nodes,
                                   layer=k, chapter=chapter)
            acts, extras = self._chapter_inputs(chapter, node)
            for j in range(k):       # Algorithm-1 forward recompute
                w_j = self._layer_params(j, node)
                acts = tuple(self._fwd(w_j, a) for a in acts)
            self._train_task(k, chapter, node, acts, extras, lrs, kc)
        if self.has_head:
            node = pff_dag.head_node_of(self.schedule, self.num_nodes,
                                        n_layers=self.n_layers,
                                        chapter=chapter)
            self._head_task(chapter, node, None, lrs_head, kc)
        if self.has_neg and self.neg.needs_scores:
            # the LAST node holds the full model freshest: it generates
            # and publishes for everyone (the paper's serialization).
            self._neg_task(chapter,
                           pff_dag.neg_node_of(self.schedule,
                                               self.num_nodes,
                                               chapter=chapter))

    # ---- elastic federated rounds (resilience.membership) ----------------
    def _run_round_elastic(self, r):
        """One elastic Federated-PFF round: every live node trains a
        COPY of the round-start model on its own shard (concurrently —
        the dispatches are async and land on distinct devices), then the
        aggregator replaces the global model with the live results
        averaged by live shard sizes. The per-node math and the
        aggregation walk nodes in sorted order and go through
        ``pff.elastic_node_round`` / ``pff.weighted_average_trees`` —
        the EXACT calls of the sequential reference
        ``pff.run_elastic_federated`` — so the multi-device round is
        bit-identical to the single-device one."""
        rc = self.resilience
        live = [n for n in pff._check_membership(rc.membership(r),
                                                 self.num_nodes, r)
                if n not in self._dead]
        if not live:
            self._rstats["chapters_skipped"] += 1
            self._rstats["elastic_rounds"].append(
                {"round": r, "live": [], "weights": []})
            return
        lrs, lrs_head = self._lrs(r)
        kr = jax.random.fold_in(self.key, r)
        # place per-node copies FIRST: the chapter trainers donate their
        # buffers, and a same-device device_put aliases — without the
        # copies node B's round would consume node A's donated input
        placed = {}
        for node in live:
            dev = self.devices[node]
            placed[node] = (
                [jax.tree_util.tree_map(jnp.copy,
                                        jax.device_put(s, dev))
                 for s in self._states],
                jax.tree_util.tree_map(jnp.copy,
                                       jax.device_put(self._head, dev)))
        per_node = {}
        first_done = False
        for node in live:
            const = self._const[node]
            idx = const["idx"]
            acts, extras = self._chapter_inputs(r, node)
            st0, head0 = placed[node]

            def body(node=node, const=const, idx=idx, acts=acts,
                     extras=extras, st0=st0, head0=head0):
                t0 = self._tracer.now()
                out = pff.elastic_node_round(
                    self.good, self.cfg, st0, head0, acts, extras, lrs,
                    lrs_head, jax.random.fold_in(kr, node),
                    epochs=self.C, impl=self.impl,
                    y=const["y"][idx] if self.has_head else None,
                    x_neutral=(const["x_neutral"][idx]
                               if self.has_head else None),
                    train_head=self.has_head)
                self._finish_task(node, "round", -1, r, t0, out[0][0][0])
                return out

            try:
                per_node[node] = self._resilient("round", -1, r, node,
                                                 body)
            except _ShardDropped:
                continue
            if not first_done:
                first_done = True
                self._maybe_kill(r, "mid")
        ok = [n for n in live if n in per_node]
        if not ok:
            self._rstats["chapters_skipped"] += 1
            self._rstats["elastic_rounds"].append(
                {"round": r, "live": [], "weights": []})
            return
        total = float(sum(int(self._const[n]["idx"].shape[0])
                          for n in ok))
        w = [int(self._const[n]["idx"].shape[0]) / total for n in ok]
        dev0 = self.devices[0]
        self._states = [pff.weighted_average_trees(
            [jax.device_put(per_node[n][0][k], dev0) for n in ok], w)
            for k in range(self.n_layers)]
        self._ver = [r] * self.n_layers
        if self.has_head:
            self._head = pff.weighted_average_trees(
                [jax.device_put(per_node[n][1], dev0) for n in ok], w)
            self._head_ver = r
        self._publish_snapshot(r)
        self._rstats["elastic_rounds"].append(
            {"round": r, "live": ok, "weights": w})

    # ---- entry point -----------------------------------------------------
    def _fresh_rstats(self):
        return {"retries": 0, "reassignments": 0, "dead_nodes": [],
                "checkpoints_written": 0, "checkpoint_time_s": 0.0,
                "restore_time_s": 0.0, "resumed_from_chapter": None,
                "recovery_time_s": 0.0, "faults_injected": {},
                "shards_dropped": 0, "chapters_skipped": 0,
                "elastic_rounds": None}

    def _publish_snapshot(self, version: int):
        """Publish the CURRENT full model (every layer + head) at one
        version — the initial pre-training snapshot, a restored
        recovery line, and the elastic federated aggregate (whose
        layers all advance together)."""
        if self._publish is None:
            return
        for k, state in enumerate(self._states):
            self._publish.publish_layer(k, version,
                                        self.good.export([state]))
        if self.has_head:
            self._publish.publish_head(version, self._head[0])

    def run(self, *, profile: bool = False,
            resume_from: Optional[str] = None,
            publish=None, trace=None) -> ExecResult:
        """Executes the schedule once. ``profile=True`` blocks after
        every task to collect per-task ``TaskRecord``s (destroys the
        overlap, so use a separate non-profiled run for makespan).

        trace: an ``obs.trace.Tracer`` (or True for a fresh one) —
        records one ``task:<kind>`` span per DAG task plus hand-off /
        retry / checkpoint events and a closing ``run`` span, all on
        the tracer's clock domain (shared with the serve loop when
        ``train_while_serve`` passes one tracer to both).
        ``ExecResult.records`` / ``node_busy`` are DERIVED from the
        task spans whenever they carry real device time (``profile``,
        or a tracer with ``block_tasks`` — the default), so every
        timeline-traced run doubles as a profile run; with
        ``block_tasks=False`` spans measure dispatch only and records
        stay None. Use a FRESH tracer per run — the analyzer treats
        all task spans in a trace as one run.

        resume_from: a chapter manifest written by a previous run (or
        its directory — the newest manifest is used); training replays
        the DAG from the first chapter after it, bit-exactly (the
        restore cost rides the timed window, like initial placement).

        publish: a ``repro.serve.WeightBus`` (anything with
        ``publish_layer``/``publish_head``) — every chapter-train task
        pushes its freshly-trained layer the moment it completes, plus
        an initial snapshot before chapter 0 (or the restored chapter),
        so serving replicas hot-swap per layer mid-run. Publication is
        read-only with copy-on-publish: the weight stream stays
        bit-exact, publish or not.
        """
        cfg = self.cfg
        rc = self.resilience
        plan = self._fault_plan
        if plan is not None:
            plan.reset()
        tracer = obs_trace.as_tracer(trace)
        if profile and not tracer.enabled:
            tracer = obs_trace.Tracer()     # profile rides the tracer now
        self._tracer = tracer
        # timeline mode: block per task so span durations are device
        # time (profile's historical semantics — destroys overlap)
        self._block = profile or (tracer.enabled and tracer.block_tasks)
        timeline = tracer.enabled and self._block
        span0 = tracer.span_count()
        # undo a previous run's dead-node remapping (benchmarks reuse
        # the executor for warm-cache timing)
        self.devices[:] = self._devices_init
        if self._const_dirty:
            self._setup_constants()
            self._const_dirty = False
        self._dead: set = set()
        self._rstats = self._fresh_rstats()
        elastic = rc is not None and rc.membership is not None
        if elastic:
            self._rstats["elastic_rounds"] = []
        params = ff_mlp.init(jax.random.PRNGKey(cfg.seed), cfg)
        opt = ff_mlp.opt_init(params)
        self._neg: Tuple[int, object] = (-1, None)
        self._ver = [-1] * self.n_layers       # chapter of last train(k)
        self._head_ver = -1
        self._publish = publish
        self._handoff = _Handoff(
            self.devices, self.overlap,
            fault_cb=plan.handoff_action if plan is not None else None,
            tracer=tracer)

        t_start = time.perf_counter()
        t_trace0 = tracer.now()
        # initial placement rides the timed window: it is part of the
        # schedule's real cost (the simulator's t=0 is the same state).
        self._states = [self.good.get_state(params, opt, k)
                        for k in range(self.n_layers)]
        self._head = (params["head"], opt["head"])
        start_chapter = 0
        if resume_from is not None:
            t0 = time.perf_counter()
            done = self._restore(resume_from)
            start_chapter = done + 1
            # sanity: the resume point must be a closed cut of the DAG
            pff_dag.replay_frontier(
                self.n_layers, cfg.splits, start_chapter,
                has_head=self.has_head, has_neg=self._ckpt_has_neg(),
                strict_neg=self._ckpt_has_neg())
            self._rstats["resumed_from_chapter"] = done
            self._rtime("restore_time_s", time.perf_counter() - t0)
        # serving replicas get a full pre-training (or restored-line)
        # snapshot before the first chapter task dispatches
        self._publish_snapshot(min([self._head_ver] + self._ver
                                   if self.has_head else self._ver))
        for chapter in range(start_chapter, cfg.splits):
            if elastic:
                self._run_round_elastic(chapter)
            elif self.schedule == "single_layer":
                self._run_chapter_single_layer(chapter)
            elif (self.schedule == "federated" and self._dead
                  and pff_dag.node_of(self.schedule, self.num_nodes,
                                      layer=0, chapter=chapter)
                  in self._dead):
                # the owning node died in an earlier chapter — its shard
                # no longer contributes (the model rests this round)
                self._rstats["chapters_skipped"] += 1
            elif self.schedule == "federated" and plan is not None:
                # a mid-chapter death must not leave a half-trained
                # chapter: snapshot the recovery line (copies — the
                # chapter trainers donate the live buffers) and roll
                # back on _ShardDropped
                snap = jax.tree_util.tree_map(
                    jnp.copy, (list(self._states), self._head))
                snap_meta = (list(self._ver), self._head_ver, self._neg)
                try:
                    self._run_chapter_owned(chapter)
                except _ShardDropped:
                    self._states, self._head = list(snap[0]), snap[1]
                    self._ver, self._head_ver, self._neg = (
                        list(snap_meta[0]), snap_meta[1], snap_meta[2])
                    self._rstats["chapters_skipped"] += 1
            else:
                self._run_chapter_owned(chapter)
            self._write_checkpoint(chapter)
            if rc is not None:
                self._maybe_kill(chapter, "post")
        outs = [s[0] for s in self._states] + [self._head[0]]
        if self._neg[1] is not None:
            outs.append(self._neg[1])
        jax.block_until_ready(outs)
        makespan = time.perf_counter() - t_start
        if tracer.enabled:
            # the closing run span carries the DAG shape so
            # obs.analyze can rebuild the exact pff_dag dependency
            # structure from the trace alone
            strict = self.has_neg and self.neg.needs_scores
            tracer.add_span(
                "run", t_trace0, schedule=self.schedule,
                num_nodes=self.num_nodes, splits=cfg.splits,
                n_layers=self.n_layers, has_head=self.has_head,
                has_neg=strict, strict_neg=strict,
                start_chapter=start_chapter, overlap=self.overlap,
                blocked=self._block, makespan_s=makespan)

        final = self._pull({**self.good.export(self._states),
                            "head": self._head[0]}, 0)
        acc = ff_mlp.accuracy(final, self.task.x_test, self.task.y_test,
                              cfg.num_classes, self.good.eval_mode(cfg),
                              impl=self.impl)
        records = node_busy = None
        if timeline:
            records, node_busy = _records_from_spans(tracer, span0,
                                                     self.num_nodes)
        res_stats = None
        if rc is not None or resume_from is not None:
            res_stats = dict(self._rstats)
            res_stats["faults_injected"] = (dict(plan.fired)
                                            if plan is not None else {})
        self._block = False
        return ExecResult(final, self.schedule, self.num_nodes, makespan,
                          acc, records, node_busy,
                          dict(self._handoff.stats), res_stats,
                          tracer if tracer.enabled else None)


def run_pff_exec(cfg, task, schedule, num_nodes, *, devices=None,
                 profile=False) -> ExecResult:
    """Deprecated: use ``repro.api.fit(cfg, task, backend="executor",
    schedule=..., num_nodes=...)``."""
    import warnings

    warnings.warn("pff_exec.run_pff_exec is deprecated; use repro.api."
                  "fit(cfg, task, backend=\"executor\", schedule=..., "
                  "num_nodes=...)", DeprecationWarning, stacklevel=2)
    from repro import api
    return api.fit(cfg, task, backend="executor", schedule=schedule,
                   num_nodes=num_nodes, devices=devices,
                   profile=profile).raw


def params_bit_equal(a, b, *, with_head=False, with_local_heads=False):
    """True iff two FF-MLP params pytrees carry BIT-IDENTICAL layer
    (and optionally head / §4.4 local-head) weights — the executor's
    correctness oracle, shared by the selftest, the benchmark gate, and
    the example."""
    def leaves_equal(pa, pb):
        return all(bool(jnp.array_equal(pa[name], pb[name]))
                   for name in ("w", "b"))
    if len(a["layers"]) != len(b["layers"]):
        return False
    ok = all(leaves_equal(pa, pb)
             for pa, pb in zip(a["layers"], b["layers"]))
    if with_head:
        ok = ok and leaves_equal(a["head"], b["head"])
    if with_local_heads:
        ok = (ok and len(a["local_heads"]) == len(b["local_heads"])
              and all(leaves_equal(pa, pb) for pa, pb in
                      zip(a["local_heads"], b["local_heads"])))
    return ok


class LMExecutor:
    """Runs the LM chapter schedule (``core.pff_lm``) for real on
    ``num_nodes`` devices — the transformer sibling of ``PFFExecutor``,
    sharing its DAG (``pff_dag``), its ``_Handoff`` transfer slots, its
    tracer conventions, and its oracle discipline.

    Bit-exactness: every task replays the EXACT jitted calls of the
    sequential reference ``pff_lm.train_chapters`` — the same
    ``make_block_step``/``make_head_step`` programs, the same
    ``chapter_batches`` stream (regenerated locally per node: the
    ``data.Source`` purity contract means training data never crosses
    the hand-off), and the same global step counters. The jit takes
    FULL (params, opt) pytrees, so each task assembles one from a
    per-node replicated template: the live slices (Algorithm-1 frozen
    prefix params, the task's own block state, the tied-embed head
    params) arrive through the ``_Handoff`` slots, and every other
    slice keeps its template (initial) value — provably dead inputs of
    the jitted program (the extracted outputs depend only on the live
    slices), so the filler can never affect the weight stream. All
    assembly is ``device_put`` / ``.at[k].set`` — pure data movement.

    Hand-off traffic per train(k, c): the block's full (params, m, v)
    state streams to the node that trains it in chapter c+1
    (``("state", k)``), and its params-only copy fans out to the
    Algorithm-1 forward-recompute / head consumers within chapter c
    (``("params", k)``) — both driven by ``pff_dag.handoff_targets``.
    The head task additionally publishes its full state toward the
    next chapter's head node (``("head",)``) and — tied embeddings
    only — its params toward every chapter-(c+1) train node
    (``("headp",)``): that is the DAG's ``head_feedback`` edge (the
    embed table every block task reads is the post-head one).
    """

    def __init__(self, cfg, source, schedule: str, num_nodes: int, *,
                 chapters: int, steps_per_chapter: int, batch: int = 8,
                 lr: float = 1e-3, head_lr: Optional[float] = None,
                 seed: int = 0, devices=None, overlap: bool = True):
        if schedule not in ("sequential", "single_layer", "all_layers"):
            raise ValueError(
                f"LM chapter executor supports sequential / single_layer"
                f" / all_layers; got {schedule!r} (federated LM shards "
                f"are ROADMAP work)")
        if schedule == "sequential" and num_nodes != 1:
            raise ValueError("sequential means num_nodes=1")
        if len(cfg.groups) != 1:
            raise ValueError("chapter schedule needs a uniform stack "
                             f"(one group); got {len(cfg.groups)}")
        self.cfg = cfg
        self.source = source
        self.schedule = schedule
        self.num_nodes = num_nodes
        self.chapters = chapters
        self.steps_per_chapter = steps_per_chapter
        self.overlap = overlap
        self.seed = seed
        self.devices = (list(devices)[:num_nodes] if devices is not None
                        else mesh_lib.pff_node_devices(num_nodes))
        self.n_layers = cfg.groups[0][1]
        self.tied = bool(cfg.tie_embeddings)
        self._head_names = pff_lm.head_param_names(cfg)
        self._step = pff_lm.make_block_step(cfg, lr=lr, seed=seed)
        self._head_step = pff_lm.make_head_step(
            cfg, head_lr=lr if head_lr is None else head_lr)
        self._data = pff_lm.chapter_batches(source, batch=batch,
                                            steps=steps_per_chapter)
        self._tracer = obs_trace.NOOP
        self._block = False

    def _finish_task(self, node, kind, layer, chapter, t0, out):
        if self._block:
            jax.block_until_ready(out)
        tr = self._tracer
        if tr.enabled:
            tr.add_span("task:" + kind, t0, kind=kind, layer=layer,
                        chapter=chapter, node=node)

    def _train_task(self, k, chapter, node):
        """One per-block chapter task: assemble the full trees on the
        node, replay ``steps_per_chapter`` sequential block steps with
        the sequential trainer's global step numbers, publish toward
        the DAG consumers."""
        t0 = self._tracer.now()
        dev = self.devices[node]
        tp, to = self._tmpl[node]
        gp = tp["groups"][0]
        for j in range(k):
            # Algorithm-1 frozen prefix: block j at chapter `chapter`
            assert self._ver[j] == chapter, (j, self._ver[j], chapter)
            pj = self._handoff.take(("params", j), node, chapter,
                                    self._blk[j][0])
            gp = pff_lm._set_unit(gp, pj, j)
        up, um, uv = self._handoff.take(("state", k), node, self._ver[k],
                                        self._blk[k])
        gp = pff_lm._set_unit(gp, up, k)
        gm = pff_lm._set_unit(to["m"]["groups"][0], um, k)
        gv = pff_lm._set_unit(to["v"]["groups"][0], uv, k)
        p = dict(tp)
        p["groups"] = (gp,)
        if self.tied:
            # head_feedback edge: the embed table this task reads is
            # the one head(chapter-1) produced
            hp = self._handoff.take(("headp",), node, self._head_ver,
                                    self._head[0])
            for name in self._head_names:
                p[name] = hp[name]
        opt = {"m": {**to["m"], "groups": (gm,)},
               "v": {**to["v"], "groups": (gv,)}}
        base = (chapter * self.n_layers + k) * self.steps_per_chapter
        last = None
        for s, batch in enumerate(self._data(chapter, k)):
            p, opt, last = self._step(p, opt, jax.device_put(batch, dev),
                                      k, base + s + 1)
        self._blk[k] = (pff_lm._slice_unit(p["groups"][0], k),
                        pff_lm._slice_unit(opt["m"]["groups"][0], k),
                        pff_lm._slice_unit(opt["v"]["groups"][0], k))
        self._ver[k] = chapter
        nxt, param_nodes = pff_dag.handoff_targets(
            self.schedule, self.num_nodes, n_layers=self.n_layers,
            splits=self.chapters, layer=k, chapter=chapter,
            has_head=True, has_neg=False)
        if nxt is not None:
            self._handoff.prefetch(("state", k), nxt, chapter,
                                   self._blk[k])
        for pn in param_nodes:
            self._handoff.prefetch(("params", k), pn, chapter,
                                   self._blk[k][0])
        self._finish_task(node, "train", k, chapter, t0, last)

    def _head_task(self, chapter, node):
        """The per-chapter softmax-head task: frozen forward through
        every chapter-c block, CE on the head subset (``pff_lm.
        make_head_step``), head state published toward chapter c+1."""
        t0 = self._tracer.now()
        dev = self.devices[node]
        tp, to = self._tmpl[node]
        gp = tp["groups"][0]
        for j in range(self.n_layers):
            assert self._ver[j] == chapter, (j, self._ver[j], chapter)
            pj = self._handoff.take(("params", j), node, chapter,
                                    self._blk[j][0])
            gp = pff_lm._set_unit(gp, pj, j)
        hp, hm, hv = self._handoff.take(("head",), node, self._head_ver,
                                        self._head)
        p = dict(tp)
        p["groups"] = (gp,)
        m, v = dict(to["m"]), dict(to["v"])
        for name in self._head_names:
            p[name], m[name], v[name] = hp[name], hm[name], hv[name]
        opt = {"m": m, "v": v}
        base = chapter * self.steps_per_chapter
        last = None
        for s, batch in enumerate(self._data(chapter, self.n_layers)):
            p, opt, last = self._head_step(
                p, opt, jax.device_put(batch, dev), base + s + 1)
        self._head = ({n: p[n] for n in self._head_names},
                      {n: opt["m"][n] for n in self._head_names},
                      {n: opt["v"][n] for n in self._head_names})
        self._head_ver = chapter
        if chapter + 1 < self.chapters:
            nh = pff_dag.head_node_of(self.schedule, self.num_nodes,
                                      n_layers=self.n_layers,
                                      chapter=chapter + 1)
            if nh != node:
                self._handoff.prefetch(("head",), nh, chapter,
                                       self._head)
            if self.tied:
                for tn in pff_dag.chapter_train_nodes(
                        self.schedule, self.num_nodes, self.n_layers,
                        chapter=chapter + 1):
                    if tn != node:
                        self._handoff.prefetch(("headp",), tn, chapter,
                                               self._head[0])
        self._finish_task(node, "head", self.n_layers, chapter, t0, last)

    def run(self, *, profile: bool = False, trace=None) -> ExecResult:
        """Executes the LM chapter schedule once. Same tracer/profile
        semantics as ``PFFExecutor.run`` (``records``/``node_busy``
        derive from the ``task:*`` spans when they carry blocked
        durations); ``test_acc`` is None — LM quality is eval CE,
        computed by the facade (``api.fit`` → ``FitResult.eval_ce``)
        so the sequential and executor paths are scored identically."""
        cfg = self.cfg
        tracer = obs_trace.as_tracer(trace)
        if profile and not tracer.enabled:
            tracer = obs_trace.Tracer()
        self._tracer = tracer
        self._block = profile or (tracer.enabled and tracer.block_tasks)
        timeline = tracer.enabled and self._block
        span0 = tracer.span_count()
        params = transformer.init(jax.random.PRNGKey(self.seed), cfg)
        opt = optim.adam_init(params)
        gp, gm, gv = (params["groups"][0], opt["m"]["groups"][0],
                      opt["v"]["groups"][0])
        # canonical state partition: per-block unit slices + head subset
        self._blk = [(pff_lm._slice_unit(gp, k),
                      pff_lm._slice_unit(gm, k),
                      pff_lm._slice_unit(gv, k))
                     for k in range(self.n_layers)]
        self._head = tuple({n: t[n] for n in self._head_names}
                           for t in (params, opt["m"], opt["v"]))
        self._ver = [-1] * self.n_layers
        self._head_ver = -1
        self._handoff = _Handoff(self.devices, self.overlap,
                                 tracer=tracer)
        t_start = time.perf_counter()
        t_trace0 = tracer.now()
        # initial placement rides the timed window (like PFFExecutor):
        # one full (params, opt) template per node — dead-slice filler
        # the per-task assembly overwrites with the live hand-off bits
        self._tmpl = {node: jax.device_put((params, opt), dev)
                      for node, dev in enumerate(self.devices)}
        for c in range(self.chapters):
            for k in range(self.n_layers):
                self._train_task(k, c, pff_dag.node_of(
                    self.schedule, self.num_nodes, layer=k, chapter=c))
            self._head_task(c, pff_dag.head_node_of(
                self.schedule, self.num_nodes, n_layers=self.n_layers,
                chapter=c))
        outs = [s[0] for s in self._blk] + [self._head[0]]
        jax.block_until_ready(outs)
        makespan = time.perf_counter() - t_start
        if tracer.enabled:
            tracer.add_span(
                "run", t_trace0, schedule=self.schedule,
                num_nodes=self.num_nodes, splits=self.chapters,
                n_layers=self.n_layers, has_head=True, has_neg=False,
                strict_neg=False, head_feedback=self.tied,
                start_chapter=0, overlap=self.overlap,
                blocked=self._block, makespan_s=makespan)
        # reassemble the canonical full params pytree on node 0 —
        # exactly the tree the sequential trainer returns
        dev0 = self.devices[0]
        fgp = jax.device_put(params["groups"][0], dev0)
        for k in range(self.n_layers):
            fgp = pff_lm._set_unit(
                fgp, jax.device_put(self._blk[k][0], dev0), k)
        final = dict(jax.device_put(params, dev0))
        final["groups"] = (fgp,)
        for name in self._head_names:
            final[name] = jax.device_put(self._head[0][name], dev0)
        records = node_busy = None
        if timeline:
            records, node_busy = _records_from_spans(tracer, span0,
                                                     self.num_nodes)
        self._block = False
        return ExecResult(final, self.schedule, self.num_nodes, makespan,
                          None, records, node_busy,
                          dict(self._handoff.stats), None,
                          tracer if tracer.enabled else None)


# ---------------------------------------------------------------------------
# Self-test: weight-stream bit-equality vs the sequential trainer.
# Run in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=4
# (tests/test_pff_exec.py does; `make pff-exec-smoke` exercises the same
# path through benchmarks/pff_exec.py).
# ---------------------------------------------------------------------------

def _case_setup(splits, n_train, neg_mode, classifier, goodness_fn):
    """The (cfg, task) every selftest case trains — shared by the
    bit-exactness matrix and the resilience cases so a fault-injected
    run is comparable against the same reference."""
    from repro.configs.ff_mlp import FFMLPConfig

    task = data_lib.mnist_like(n_train=n_train, n_test=200)
    # kernel_impl pinned to "ref": this matrix promises BIT-exactness,
    # and a populated tuning table may legitimately steer impl="auto"
    # onto a Pallas block shape whose float summation order differs.
    # The tuned path is gated on the 1e-4 oracle error instead (see
    # kernels.autotune.TABLE_META) — pinning keeps this gate green with
    # tuning on or off.
    cfg = FFMLPConfig(layer_sizes=(784, 128, 128), epochs=splits * 2,
                      splits=splits, neg_mode=neg_mode,
                      classifier=classifier, goodness_fn=goodness_fn,
                      batch_size=64, kernel_impl="ref", seed=0)
    return cfg, task


def _check_case(schedule, nodes, splits, n_train, neg_mode, classifier,
                goodness_fn="sumsq", *, check_sim_bound=False,
                check_overlap_ab=False):
    """Trains one config both ways — THROUGH THE FACADE (``api.fit``) —
    and returns a list of failure strings (empty = the executor
    reproduced the sequential trainer's weight stream bit-exactly).

    check_overlap_ab: additionally runs the executor with the
    double-buffered hand-off DISABLED and requires the overlap-on and
    overlap-off weight streams to be bit-identical to each other (the
    prefetched copies must be the same bits as the on-demand pulls)."""
    from repro import api

    cfg, task = _case_setup(splits, n_train, neg_mode, classifier,
                            goodness_fn)
    if schedule == "federated":
        ref = api.fit(cfg, task, backend="federated", num_nodes=nodes)
    else:
        ref = api.fit(cfg, task, backend="sequential")
    res = api.fit(cfg, task, backend="executor", schedule=schedule,
                  num_nodes=nodes)

    failures = []
    perf_opt = goodness_fn == "perf_opt"
    if check_overlap_ab:
        off = api.fit(cfg, task, backend="executor", schedule=schedule,
                      num_nodes=nodes, overlap=False)
        stats_on, stats_off = res.raw.handoff, off.raw.handoff
        if not params_bit_equal(off.params, res.params,
                                with_head=classifier == "softmax",
                                with_local_heads=perf_opt):
            failures.append(f"{schedule}: overlap-on vs overlap-off "
                            "weight streams diverged")
        if stats_off["prefetch_issued"] != 0:
            failures.append(f"{schedule}: overlap=False still issued "
                            f"{stats_off['prefetch_issued']} prefetches")
        if nodes > 1 and stats_on["prefetch_hits"] == 0:
            failures.append(f"{schedule}: overlap=True never hit a "
                            f"prefetched slot ({stats_on})")
        print(f"  overlap A/B {schedule}: on={stats_on} off={stats_off}")
    if not params_bit_equal(ref.params, res.params,
                            with_head=classifier == "softmax",
                            with_local_heads=perf_opt):
        # diagnose which leaves diverged and by how much
        named = [(f"layer {k}", lp_ref, lp_ex) for k, (lp_ref, lp_ex) in
                 enumerate(zip(ref.params["layers"], res.params["layers"]))]
        if classifier == "softmax":
            named.append(("head", ref.params["head"], res.params["head"]))
        if perf_opt:
            named += [(f"local_head {k}", h_ref, h_ex)
                      for k, (h_ref, h_ex) in
                      enumerate(zip(ref.params["local_heads"],
                                    res.params["local_heads"]))]
        for label, pa, pb in named:
            for name in ("w", "b"):
                if not bool(jnp.array_equal(pa[name], pb[name])):
                    err = float(jnp.abs(pa[name] - pb[name]).max())
                    failures.append(f"{schedule}: {label} {name} diverged,"
                                    f" max|diff|={err:.3e}")
    sim_note = ""
    if check_sim_bound:
        # Sanity bound, deliberately loose (shared-core container, cold
        # executor caches): a real run can never beat the simulator's
        # perfect-overlap replay of the same median task times by 4x.
        sim = pff.simulate_schedule(ref.records, schedule, nodes)
        sim_note = f" sim={sim.makespan:.2f}s"
        if res.makespan < 0.25 * sim.makespan:
            failures.append(
                f"{schedule}: measured makespan {res.makespan:.3f}s "
                f"implausibly beats the simulator's perfect-overlap "
                f"prediction {sim.makespan:.3f}s by more than 4x")
    print(f"devices={len(jax.devices())} schedule={schedule} "
          f"nodes={nodes} neg={neg_mode} cls={classifier} "
          f"goodness={goodness_fn}: "
          f"exec acc={res.test_acc:.4f} seq acc={ref.test_acc:.4f} "
          f"makespan={res.makespan:.2f}s{sim_note} -> "
          + ("FAIL" if failures else "bit-exact"))
    return failures


# (schedule, nodes, splits, n_train, neg_mode, classifier[, goodness_fn])
# n_train=520: 520 % 64 != 0 — the tail-batch path is always exercised;
# federated shards of 130 hit a different (also non-divisible) tail.
# The perf_opt rows check the §4.4 path (fused per-layer local-head
# task) end to end, including the single_layer forward recompute.
# The _AB_CASES rows double as the double-buffering A/B gate: row 1
# (all_layers adaptive softmax) routes published negatives, the softmax
# head and full layer states through the next-chapter prefetch; row 3
# (single_layer random) covers the params-only forward-recompute
# fan-out; row 6 (single_layer adaptive softmax) covers the
# single_layer head-node and published-negatives fan-out paths, which
# rows 1/3 never create slots for.
_MATRIX = (
    ("all_layers", 4, 4, 520, "random", "goodness"),
    ("all_layers", 4, 3, 520, "adaptive", "softmax"),
    ("federated", 4, 4, 520, "random", "goodness"),
    ("single_layer", 2, 3, 520, "random", "goodness"),
    ("all_layers", 4, 3, 520, "random", "goodness", "perf_opt"),
    ("single_layer", 2, 3, 520, "random", "goodness", "perf_opt"),
    ("single_layer", 2, 3, 520, "adaptive", "softmax"),
)
# rows that additionally run the overlap-on vs overlap-off comparison
_AB_CASES = (1, 3, 6)


def _lm_case_setup(n_blocks, tied, *, seq_len=16):
    """The (cfg, source) every LM selftest case trains: a tiny
    qwen2-0.5b-shaped stack over the real-text BPE source — the same
    construction ``benchmarks/lm_exec.py`` and ``tests/test_pff_lm.py``
    use."""
    from repro.configs import get_config

    cfg = get_config("qwen2-0.5b").reduced()
    cfg = dataclasses.replace(cfg, num_layers=n_blocks,
                              groups=((("attn",), n_blocks),))
    if not tied:
        cfg = dataclasses.replace(cfg, tie_embeddings=False)
    source = data_lib.text_source(vocab=cfg.vocab, seq_len=seq_len,
                                  seed=0)
    return cfg, source


def _lm_check_case(schedule, nodes, n_blocks, chapters, steps, tied, *,
                   check_overlap_ab=False):
    """Trains one LM config both ways — through ``api.fit`` — and
    returns failure strings (empty = the executor reproduced the
    sequential ``train_chapters`` weight stream bit-exactly)."""
    from repro import api

    cfg, source = _lm_case_setup(n_blocks, tied)
    kw = dict(chapters=chapters, steps_per_chapter=steps, batch=4,
              lr=1e-3)
    ref = api.fit(cfg, source, backend="sequential", **kw)
    res = api.fit(cfg, source, backend="executor", schedule=schedule,
                  num_nodes=nodes, **kw)
    failures = []
    if not pff_lm.lm_params_bit_equal(ref.params, res.params):
        failures.append(f"lm {schedule}: executor weight stream "
                        f"diverged from sequential train_chapters "
                        f"(tied={tied})")
    if check_overlap_ab:
        off = api.fit(cfg, source, backend="executor", schedule=schedule,
                      num_nodes=nodes, overlap=False, **kw)
        stats_on, stats_off = res.raw.handoff, off.raw.handoff
        if not pff_lm.lm_params_bit_equal(off.params, res.params):
            failures.append(f"lm {schedule}: overlap-on vs overlap-off "
                            "weight streams diverged")
        if stats_off["prefetch_issued"] != 0:
            failures.append(f"lm {schedule}: overlap=False still issued "
                            f"{stats_off['prefetch_issued']} prefetches")
        if nodes > 1 and stats_on["prefetch_hits"] == 0:
            failures.append(f"lm {schedule}: overlap=True never hit a "
                            f"prefetched slot ({stats_on})")
        print(f"  lm overlap A/B {schedule}: on={stats_on} "
              f"off={stats_off}")
    print(f"devices={len(jax.devices())} lm schedule={schedule} "
          f"nodes={nodes} blocks={n_blocks} tied={tied}: "
          f"exec ce={res.eval_ce:.4f} seq ce={ref.eval_ce:.4f} "
          f"makespan={res.makespan:.2f}s -> "
          + ("FAIL" if failures else "bit-exact"))
    return failures


# (schedule, nodes, n_blocks, chapters, steps_per_chapter, tied)
# Row 1/2: the acceptance-criteria pair — both paper schedules, N=4
# faked devices, tied embeddings (the head_feedback edge: every block
# task must see the post-head embed table), with the overlap A/B gate.
# Row 3: untied head (lm_head path) + nodes not dividing the block
# count, so the single_layer round-robin wraps.
_LM_MATRIX = (
    ("all_layers", 4, 4, 3, 2, True),
    ("single_layer", 4, 4, 3, 2, True),
    ("single_layer", 2, 3, 2, 2, False),
)
_LM_AB_CASES = (0, 1)


def _resilience_case(args):
    """One resilience run from the CLI: inject ``--fault-plan``, write
    chapter manifests into ``--checkpoint-dir``, resume from
    ``--resume-from`` — and gate the surviving weight stream against the
    fault-free reference with ``params_bit_equal`` wherever the policy
    promises bit-exactness (everywhere except federated shard drops,
    which degrade gracefully instead). A ``kill_*`` plan exits the
    process with ``faults.KILL_EXIT`` before any comparison — the
    caller re-invokes with ``--resume-from`` (what
    ``benchmarks/pff_faults.py`` and the subprocess tests do)."""
    from repro import api

    cfg, task = _case_setup(args.splits, args.n_train, args.neg_mode,
                            args.classifier, args.goodness_fn)
    plan = None
    if args.fault_plan:
        plan = faults_lib.named_plan(
            args.fault_plan, splits=cfg.splits,
            n_layers=len(cfg.layer_sizes) - 1, num_nodes=args.nodes)
    rc = faults_lib.ResilienceConfig(checkpoint_dir=args.checkpoint_dir,
                                     fault_plan=plan)
    res = api.fit(cfg, task, backend="executor", schedule=args.schedule,
                  num_nodes=args.nodes, resilience=rc,
                  resume_from=args.resume_from)
    stats = res.raw.resilience
    print(f"resilience {args.schedule} nodes={args.nodes} "
          f"plan={args.fault_plan or '-'} "
          f"resume={'yes' if args.resume_from else 'no'}: "
          f"acc={res.test_acc:.4f} retries={stats['retries']} "
          f"reassignments={stats['reassignments']} "
          f"dead={stats['dead_nodes']} "
          f"faults={stats['faults_injected']}")
    degraded = stats["shards_dropped"] or stats["chapters_skipped"]
    if degraded:
        print("  federated shard-drop degradation: bit-exactness gate "
              "skipped (weighted rounds lost a shard by design)")
        return []
    if args.schedule == "federated":
        ref = api.fit(cfg, task, backend="federated",
                      num_nodes=args.nodes)
    else:
        ref = api.fit(cfg, task, backend="sequential")
    if not params_bit_equal(ref.params, res.params,
                            with_head=args.classifier == "softmax",
                            with_local_heads=args.goodness_fn
                            == "perf_opt"):
        return [f"{args.schedule}: resilient run diverged from the "
                f"fault-free reference (plan={args.fault_plan})"]
    print("  bit-exact vs fault-free reference")
    return []


def _selftest(argv=None):
    import argparse

    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--matrix", action="store_true",
                   help="run the full schedule/neg/classifier matrix "
                        "in one process (what tests/test_pff_exec.py "
                        "invokes)")
    p.add_argument("--lm-matrix", action="store_true",
                   help="run the LM chapter-schedule bit-exactness "
                        "matrix (executor vs pff_lm.train_chapters on "
                        "the real-text BPE source; what "
                        "tests/test_pff_lm.py invokes)")
    p.add_argument("--nodes", type=int, default=4)
    p.add_argument("--schedule", default="all_layers",
                   choices=list(pff_dag.SCHEDULES))
    p.add_argument("--splits", type=int, default=4)
    p.add_argument("--n-train", type=int, default=1000,
                   help="deliberately NOT divisible by the batch size, "
                        "so the tail-batch path is exercised too")
    p.add_argument("--neg-mode", default="random",
                   choices=list(strategies.negatives.names()))
    p.add_argument("--classifier", default="goodness",
                   choices=list(strategies.classifier.names()))
    p.add_argument("--goodness-fn", default="sumsq",
                   choices=list(strategies.goodness.names()))
    p.add_argument("--fault-plan", default=None,
                   choices=sorted(faults_lib.NAMED_PLANS),
                   help="inject a named deterministic fault plan "
                        "(repro.core.faults.NAMED_PLANS) and gate the "
                        "surviving weight stream")
    p.add_argument("--checkpoint-dir", default=None,
                   help="write chapter-granular manifests here (one "
                        "atomic .npz per completed chapter)")
    p.add_argument("--resume-from", default=None,
                   help="chapter manifest (or its directory: newest "
                        "wins) to resume from — replays the DAG from "
                        "the next chapter bit-exactly")
    args = p.parse_args(argv)

    failures = []
    if args.fault_plan or args.checkpoint_dir or args.resume_from:
        failures = _resilience_case(args)
    elif args.matrix:
        for i, case in enumerate(_MATRIX):
            failures += _check_case(*case, check_sim_bound=i == 0,
                                    check_overlap_ab=i in _AB_CASES)
    elif args.lm_matrix:
        for i, case in enumerate(_LM_MATRIX):
            failures += _lm_check_case(
                *case, check_overlap_ab=i in _LM_AB_CASES)
        if not failures:
            print("lm selftest OK: executor chapter schedule bit-exact "
                  "vs train_chapters on the BPE text source")
    else:
        failures = _check_case(args.schedule, args.nodes, args.splits,
                               args.n_train, args.neg_mode,
                               args.classifier, args.goodness_fn,
                               check_sim_bound=True,
                               check_overlap_ab=True)
    if failures:
        print("SELFTEST FAILED:\n  " + "\n  ".join(failures))
        return 1
    print("selftest OK: executor weight stream bit-exact vs the "
          "sequential trainer")
    return 0


if __name__ == "__main__":
    raise SystemExit(_selftest())
