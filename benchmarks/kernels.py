"""Kernel validation sweep: every Pallas kernel vs its oracle across a
shape grid, max-abs-error reported. (Wall-time is meaningless in
interpret mode on CPU — correctness is the deliverable here; the TPU
perf story lives in the roofline analysis.)

``run_tune`` is the autotuner sweep behind ``make tune-smoke`` /
``--only=tune``: it runs the measure-many pick-fastest pass, reports
each winner as %-of-roofline (the load-insensitive framing), writes
``BENCH_kernel_tune.json``, and gates the table plumbing end-to-end —
table written, re-lookup a pure memo hit, ``impl="auto"`` resolving
through the winners, a poisoned entry degrading to defaults with a
warning instead of crashing.
"""
from __future__ import annotations

import json
import os
import warnings

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.ff_dense import ff_dense
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba2_ssd import mamba2_ssd


def run():
    """Prints the sweep and returns the worst max-abs error across every
    kernel/shape, so run.py can fail loudly on a regression."""
    worst = 0.0
    key = jax.random.PRNGKey(0)
    print("ff_dense:")
    for M, K, N in [(64, 784, 2000), (128, 3072, 400), (256, 256, 256)]:
        x = jax.random.normal(key, (M, K))
        w = jax.random.normal(key, (K, N)) * K ** -0.5
        b = jnp.zeros((N,))
        y, g = ff_dense(x, w, b)
        yr, gr = ref.ff_dense_ref(x, w, b)
        err = max(float(jnp.abs(y - yr).max()),
                  float(jnp.abs(g - gr).max() / (float(gr.max()) + 1e-9)))
        worst = max(worst, err)
        print(f"  ({M},{K},{N}): max_err={err:.2e}")

    print("flash_attention:")
    for B, S, H, KV, hd, causal, win in [(2, 256, 8, 2, 64, True, None),
                                         (1, 256, 4, 1, 128, True, 128),
                                         (2, 128, 4, 4, 64, False, None)]:
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (B, S, H, hd))
        k = jax.random.normal(ks[1], (B, S, KV, hd))
        v = jax.random.normal(ks[2], (B, S, KV, hd))
        o = flash_attention(q, k, v, causal=causal, window=win,
                            bq=64, bk=64)
        orf = ref.flash_attention_ref(q, k, v, causal=causal, window=win)
        err = float(jnp.abs(o - orf).max())
        worst = max(worst, err)
        print(f"  B{B} S{S} H{H}/{KV} hd{hd} causal={causal} win={win}: "
              f"max_err={err:.2e}")

    print("mamba2_ssd:")
    for B, S, H, hd, N, chunk in [(2, 256, 8, 32, 64, 64),
                                  (1, 512, 4, 64, 128, 128)]:
        ks = jax.random.split(key, 4)
        xbar = jax.random.normal(ks[0], (B, S, H, hd))
        dA = -jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
        b = jax.random.normal(ks[2], (B, S, N))
        c = jax.random.normal(ks[3], (B, S, N))
        y, hT = mamba2_ssd(xbar, dA, b, c, chunk=chunk)
        yr, hTr = ref.mamba2_ssd_ref(xbar, dA, b, c)
        # scale-normalized (same convention as the ff_dense goodness
        # entry): the long-scan outputs are O(10), where float32
        # reassociation alone moves the raw max-abs past 1e-4
        err = max(float(jnp.abs(y - yr).max() /
                        (float(jnp.abs(yr).max()) + 1e-9)),
                  float(jnp.abs(hT - hTr).max() /
                        (float(jnp.abs(hTr).max()) + 1e-9)))
        worst = max(worst, err)
        print(f"  B{B} S{S} H{H} hd{hd} N{N} L{chunk}: max_err={err:.2e}")
    return worst


# ---------------------------------------------------------------------------
# Autotuner sweep (``--only=tune`` / ``make tune-smoke``)
# ---------------------------------------------------------------------------

# two shape buckets with different M so the candidate grids differ and
# the selected blocks can too; quick keeps interpret-mode wall time low
_TUNE_SHAPES_QUICK = [(32, 128, 256), (128, 512, 512)]
_TUNE_SHAPES_FULL = [(32, 128, 256), (64, 784, 2000), (128, 512, 512),
                     (100, 333, 257)]

_OUT_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         os.pardir, "BENCH_kernel_tune.json")


def _check_memo(autotune, rows, platform, failures):
    """After a save, lookups must read the file once then run from the
    in-memory memo — the 'pure memo hit' gate."""
    autotune.invalidate_cache()
    tuned = [r for r in rows if r["winner"] is not None]
    loads0 = autotune.STATS["loads"]
    for r in tuned:
        e = autotune.lookup("ff_dense", r["M"], r["K"], r["N"],
                            jnp.float32, platform, norm=r["norm"])
        if e is None:
            failures.append(f"tune: lookup miss for tuned bucket "
                            f"{r['key']}")
    loads_after_first = autotune.STATS["loads"]
    hits0 = autotune.STATS["memo_hits"]
    for r in tuned:                      # the re-run: zero file reads
        autotune.lookup("ff_dense", r["M"], r["K"], r["N"],
                        jnp.float32, platform, norm=r["norm"])
    if loads_after_first - loads0 != 1:
        failures.append(f"tune: first lookup pass read the table "
                        f"{loads_after_first - loads0} times (want 1)")
    if autotune.STATS["loads"] != loads_after_first:
        failures.append("tune: re-lookup re-read the table instead of "
                        "hitting the memo")
    if autotune.STATS["memo_hits"] - hits0 < len(tuned):
        failures.append("tune: re-lookup pass was not a pure memo hit")


def _check_poisoned(autotune, ops_mod, rows, platform, failures):
    """Corrupt one persisted winner, point the process at the poisoned
    copy, and require warn-and-default rather than a crash."""
    tuned = [r for r in rows if r["winner"] is not None]
    if not tuned:
        return
    r = tuned[0]
    src = autotune.TuneTable.open()
    poisoned_path = src.path + ".poisoned"
    bad = autotune.TuneTable(poisoned_path)
    bad.entries = {k: dict(v) for k, v in src.entries.items()}
    bad.entries[r["key"]]["bm"] = "not-an-int"
    bad.save()
    prev = os.environ.get("REPRO_TUNE_TABLE")
    os.environ["REPRO_TUNE_TABLE"] = poisoned_path
    autotune.invalidate_cache()
    try:
        with warnings.catch_warnings(record=True) as wlog:
            warnings.simplefilter("always")
            entry = autotune.lookup("ff_dense", r["M"], r["K"], r["N"],
                                    jnp.float32, platform,
                                    norm=r["norm"])
            key = jax.random.PRNGKey(3)
            x = jax.random.normal(key, (r["M"], r["K"]))
            w = jax.random.normal(key, (r["K"], r["N"])) * r["K"] ** -0.5
            b = jnp.zeros((r["N"],))
            y, g = ops_mod.ff_dense(x, w, b, norm=r["norm"])
        if entry is not None:
            failures.append("tune: poisoned entry was not rejected by "
                            "lookup validation")
        if not any("poisoned" in str(m.message) for m in wlog):
            failures.append("tune: poisoned entry produced no warning")
        if not (bool(jnp.isfinite(y).all()) and bool(jnp.isfinite(g).all())):
            failures.append("tune: fallback path after poisoned entry "
                            "produced non-finite output")
        else:
            print(f"  poisoned-entry fallback: lookup rejected, "
                  f"{len(wlog)} warning(s), defaults ran clean")
    finally:
        if prev is None:
            os.environ.pop("REPRO_TUNE_TABLE", None)
        else:
            os.environ["REPRO_TUNE_TABLE"] = prev
        autotune.invalidate_cache()
        os.remove(poisoned_path)


def run_tune(quick=True, out_path=None):
    """The tuning sweep + its smoke gates; returns {"failures": [...]}
    for run.py and writes BENCH_kernel_tune.json."""
    from benchmarks import roofline
    from repro.kernels import autotune, ops as ops_mod

    failures = []
    platform = jax.default_backend()
    shapes = _TUNE_SHAPES_QUICK if quick else _TUNE_SHAPES_FULL
    print(f"tuning table: {autotune.table_path()}")
    rows = autotune.tune_ff_dense(
        shapes, norms=(False, True),
        max_candidates=3 if quick else None, seed=0)

    # gate: the table landed on disk
    path = autotune.table_path()
    if not os.path.exists(path):
        failures.append(f"tune: table not written to {path}")

    # gate: every tuned bucket's winner honors the 1e-4 oracle budget
    blocks_seen = set()
    for r in rows:
        w = r["winner"]
        if w is None:
            failures.append(f"tune: no candidate passed the gate for "
                            f"{r['key']}")
            continue
        if w["err"] > autotune.ERR_GATE or w["grad_err"] > autotune.ERR_GATE:
            failures.append(
                f"tune: persisted winner for {r['key']} breaches the "
                f"gate (err={w['err']:.2e} grad_err={w['grad_err']:.2e})")
        if "bm" in w:
            blocks_seen.add((w["bm"], w["bn"]))
        roof = roofline.ff_dense_roofline(r["M"], r["K"], r["N"],
                                          platform=platform)
        r["roofline"] = {
            "roof_s": roof["roof_s"], "bound": roof["bound"],
            "winner_pct_of_roof": roofline.pct_of_roofline(
                w["time_s"], roof["roof_s"]),
            "pallas_pct_of_roof": roofline.pct_of_roofline(
                w.get("pallas_time_s", 0.0), roof["roof_s"]),
        }
        blk = f" bm={w['bm']} bn={w['bn']}" if "bm" in w else ""
        print(f"  {r['key']}: winner={w['impl']}{blk} "
              f"{r['roofline']['winner_pct_of_roof']:.3g}% of "
              f"{roof['bound']}-bound roof "
              f"({roof['roof_s'] * 1e6:.1f}us analytic)")

    # gate: tuned blocks actually vary across shape buckets
    if len([r for r in rows if r["winner"]]) >= 2 and len(blocks_seen) < 2:
        failures.append(f"tune: every shape bucket selected the same "
                        f"blocks {blocks_seen} — sweep is degenerate")

    _check_memo(autotune, rows, platform, failures)

    # gate: impl="auto" end-to-end through registry + table
    M, K, N = shapes[0]
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (M, K))
    w = jax.random.normal(key, (K, N)) * K ** -0.5
    b = jnp.zeros((N,))
    ya, ga = ops_mod.ff_dense(x, w, b, impl="auto")
    yr, gr = ref.ff_dense_ref(x, w, b)
    auto_err = max(
        float(jnp.abs(ya - yr).max() / (jnp.abs(yr).max() + 1e-9)),
        float(jnp.abs(ga - gr).max() / (jnp.abs(gr).max() + 1e-9)))
    if auto_err > autotune.ERR_GATE:
        failures.append(f"tune: impl='auto' through the tuned table "
                        f"err {auto_err:.2e} > {autotune.ERR_GATE:.0e}")
    else:
        print(f"  impl='auto' vs oracle after tuning: {auto_err:.1e}")

    _check_poisoned(autotune, ops_mod, rows, platform, failures)

    out_path = out_path or _OUT_PATH
    with open(out_path, "w") as f:
        json.dump({"platform": platform,
                   "interpret": platform != "tpu",
                   "table_path": path,
                   "err_gate": autotune.ERR_GATE,
                   "distinct_blocks": sorted(blocks_seen),
                   "stats": dict(autotune.STATS),
                   "rows": rows,
                   "failures": failures}, f, indent=2)
        f.write("\n")
    print(f"  wrote {os.path.normpath(out_path)} ({len(rows)} buckets, "
          f"{len(blocks_seen)} distinct block shapes)")
    return {"failures": failures, "rows": rows}
