"""Thread-safe tracing core for ``repro.obs``.

One ``Tracer`` owns one monotonic clock domain (``time.perf_counter``
anchored at construction), so spans recorded from the executor driver
thread, the serve loop, and the ``train_while_serve`` background
thread all land on a single comparable timeline. Producers record:

* ``span(name, **attrs)`` — a context manager for a timed region, or
  the manual ``add_span(name, t0, t1=None, **attrs)`` when the region
  does not nest lexically (the executor opens a task span before an
  async JAX dispatch and closes it after ``block_until_ready``).
* ``event(name, **attrs)`` — an instantaneous marker (prefetch hit,
  retry, shed, version-vector violation, ...).
* ``counter(name, value)`` — an accumulating scalar (checkpoint /
  restore / recovery seconds, folding the executor's scattered
  resilience timers onto the tracer).

The default tracer is the module-level ``NOOP`` singleton: every hot
path in the repo calls through it unconditionally, and its methods are
constant-time attribute hits that allocate nothing, so an untraced run
pays only a few ``enabled``-flag checks (the ``<2%`` overhead gate in
``benchmarks/trace.py`` measures exactly this). Producers that would
do real work just to *build* a span (formatting attrs, snapshotting
queue depths) must guard on ``tracer.enabled`` first.

``block_tasks`` is the JAX-async knob: with it (the default) the
executor calls ``jax.block_until_ready`` before closing each task
span, so span durations are real device time and the analyzer's
critical path is meaningful — at the cost of serializing per-task
overlap (an observer effect). With ``block_tasks=False`` spans measure
dispatch only; ``benchmarks/trace.py`` therefore uses a two-run
protocol (traced+blocked run for the timeline, untraced warm run for
the makespan) mirroring ``benchmarks/pff_exec.py``.

This module imports nothing from the rest of the repo (and no jax), so
``checkpoint.py`` and every ``core``/``serve`` module can depend on it
without import cycles.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from typing import Any, Dict, List, Optional


@dataclasses.dataclass
class Span:
    """A closed timed region on the tracer's clock (seconds since t0)."""
    name: str
    t0: float
    t1: float
    thread: str
    attrs: Dict[str, Any]

    @property
    def duration(self) -> float:
        return self.t1 - self.t0


@dataclasses.dataclass
class Event:
    """An instantaneous marker on the tracer's clock."""
    name: str
    t: float
    thread: str
    attrs: Dict[str, Any]


class Tracer:
    """Collects spans/events/counters on one shared monotonic clock.

    Thread-safe: ``add_span``/``event``/``counter`` may be called
    concurrently from any thread; each record carries the recording
    thread's name (the Chrome exporter maps it to ``tid``).
    """

    enabled = True

    def __init__(self, *, block_tasks: bool = True,
                 meta: Optional[Dict[str, Any]] = None):
        self.block_tasks = block_tasks
        self.meta: Dict[str, Any] = dict(meta or {})
        self.spans: List[Span] = []
        self.events: List[Event] = []
        self.counters: Dict[str, float] = {}
        self._t0 = time.perf_counter()
        self._lock = threading.Lock()

    # -- clock ------------------------------------------------------------
    def now(self) -> float:
        """Seconds since this tracer was created (monotonic)."""
        return time.perf_counter() - self._t0

    # -- recording --------------------------------------------------------
    def add_span(self, name: str, t0: float, t1: Optional[float] = None,
                 **attrs) -> Span:
        """Record a region [t0, t1] (both in ``now()`` time; t1 defaults
        to the current instant)."""
        if t1 is None:
            t1 = self.now()
        sp = Span(name, t0, t1, threading.current_thread().name, attrs)
        with self._lock:
            self.spans.append(sp)
        return sp

    @contextlib.contextmanager
    def span(self, name: str, **attrs):
        t0 = self.now()
        try:
            yield self
        finally:
            self.add_span(name, t0, **attrs)

    def event(self, name: str, **attrs) -> Event:
        ev = Event(name, self.now(), threading.current_thread().name, attrs)
        with self._lock:
            self.events.append(ev)
        return ev

    def counter(self, name: str, value: float = 1.0) -> None:
        """Accumulate ``value`` onto the named counter."""
        with self._lock:
            self.counters[name] = self.counters.get(name, 0.0) + value

    # -- reading ----------------------------------------------------------
    def span_count(self) -> int:
        with self._lock:
            return len(self.spans)

    def snapshot(self, *, start: int = 0) -> List[Span]:
        """A consistent copy of ``spans[start:]`` (appends-only list, so
        the slice is the spans recorded since ``span_count()`` returned
        ``start``)."""
        with self._lock:
            return list(self.spans[start:])

    def to_dict(self) -> Dict[str, Any]:
        """Plain-data form consumed by the exporters and the analyzer."""
        with self._lock:
            return {
                "meta": dict(self.meta),
                "spans": [dataclasses.asdict(s) for s in self.spans],
                "events": [dataclasses.asdict(e) for e in self.events],
                "counters": dict(self.counters),
            }


class _NoopTracer:
    """Shared disabled tracer: the zero-overhead default.

    Records nothing; every method is a cheap constant. ``span()``
    returns one reusable null context manager (no allocation per
    call).
    """

    enabled = False
    block_tasks = False
    meta: Dict[str, Any] = {}
    spans: List[Span] = []
    events: List[Event] = []
    counters: Dict[str, float] = {}

    def __init__(self):
        self._null_cm = contextlib.nullcontext(self)

    def now(self) -> float:
        return 0.0

    def add_span(self, name, t0, t1=None, **attrs):
        return None

    def span(self, name, **attrs):
        return self._null_cm

    def event(self, name, **attrs):
        return None

    def counter(self, name, value=1.0):
        return None

    def span_count(self) -> int:
        return 0

    def snapshot(self, *, start: int = 0):
        return []

    def to_dict(self):
        return {"meta": {}, "spans": [], "events": [], "counters": {}}


NOOP = _NoopTracer()


def as_tracer(trace) -> "Tracer | _NoopTracer":
    """Normalize an ``api``-level ``trace=`` argument.

    ``None``/``False`` -> ``NOOP``; ``True`` -> a fresh ``Tracer()``;
    an existing tracer object passes through (anything with ``enabled``
    and ``add_span`` duck-types).
    """
    if trace is None or trace is False:
        return NOOP
    if trace is True:
        return Tracer()
    return trace
