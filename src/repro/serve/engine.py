"""The serve loop and the combined train-while-serve driver.

``run_serve`` is one wall-clock continuous-batching loop: replay the
stream's open-loop arrivals against real time, admit into the bounded
queue (shedding on overload), form batches under the max-batch /
max-wait knobs, hot-swap the replica between batches, score through the
fused kernel path. ``train_while_serve`` runs ``PFFExecutor.run(
publish=bus)`` in a background thread and serves from the SAME bus
while training is in flight — the train-while-serving workload ROADMAP
item 2 names, and the first place two drivers share live weights.

``repro.api.serve()`` is the supported entry point; this module is the
machinery behind it.

Observability: every entry point takes ``tracer=`` (an ``obs.trace``
tracer, default the no-op singleton). The loop records admission /
batch-form / score spans and shed events; the replica records
swap-install spans and violation events on the SAME tracer. In
combined mode ``train_while_serve`` hands that one tracer to the
executor thread too, so training task spans and serving spans share a
single clock domain — the whole train-while-serve run is one Perfetto
timeline.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import List, Optional

import numpy as np

from repro import data as data_lib
from repro.obs import trace as obs_trace
from repro.serve.batcher import Batcher
from repro.serve.bus import WeightBus
from repro.serve.queue import AdmissionQueue, Request
from repro.serve.replica import Replica
from repro.serve.traffic import RequestStream, traffic as traffic_registry

_IDLE_SLEEP_S = 0.0005


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Knobs of one serving run (``api.serve`` / ``api.fit(serve=...)``).

    ``rate`` is the nominal open-loop arrival rate (requests/second);
    ``n_requests`` bounds a serve-only run (ignored while training runs
    underneath — there the loop serves until the trainer finishes);
    ``final_probe`` requests are served AFTER the last hot-swap so the
    accuracy-vs-time curve always has a window at the final weights.
    """
    traffic: str = "uniform"
    rate: float = 300.0
    n_requests: Optional[int] = None
    max_batch: int = 64
    max_wait_s: float = 0.02
    queue_cap: int = 512
    seed: int = 0
    final_probe: int = 128

    def __post_init__(self):
        if self.traffic not in traffic_registry:
            raise ValueError(
                f"unknown traffic strategy {self.traffic!r}; registered: "
                f"{', '.join(traffic_registry.names())}")


@dataclasses.dataclass
class EngineResult:
    """Raw output of one serve loop (``api.ServeResult`` wraps it)."""
    requests: List[Request]          # completed, in scoring order
    swaps: List[dict]                # replica install timeline
    consistency_violations: int
    queue_stats: dict
    bus_stats: dict
    timings: dict                    # serve_s (+ train_s when combined)
    exec_result: Optional[object] = None   # pff_exec.ExecResult
    train_error: Optional[BaseException] = None


def _score_batch(replica: Replica, batch: List[Request], now):
    x = np.stack([r.x for r in batch])
    preds = replica.predict(x)
    t_done = now()
    for r, p in zip(batch, preds):
        r.pred = int(p)
        r.version = replica.version
        r.t_done = t_done


def run_serve(replica: Replica, bus: WeightBus, stream: RequestStream,
              sconfig: ServeConfig, *, producer_done=None,
              tracer=obs_trace.NOOP) -> EngineResult:
    """The continuous-batching loop.

    ``producer_done`` (a callable -> bool) marks the training thread's
    completion in combined mode: the loop then drains every remaining
    snapshot and serves ``final_probe`` more requests at the final
    weights before stopping. Without it the loop stops after
    ``n_requests`` completions (serve-only replay).
    """
    n_target = sconfig.n_requests if producer_done is None else None
    if producer_done is None and n_target is None:
        raise ValueError("serve-only mode needs ServeConfig.n_requests")
    if tracer.enabled:
        # swap-install spans / violation events land on the loop's
        # tracer (one clock domain with the executor in combined mode)
        replica.tracer = tracer
    t_loop0 = tracer.now()
    queue = AdmissionQueue(sconfig.queue_cap)
    batcher = Batcher(sconfig.max_batch, sconfig.max_wait_s)
    done: List[Request] = []
    t0 = time.perf_counter()
    now = lambda: time.perf_counter() - t0
    upcoming = []                    # reversed [(t_arrival, Request)]
    admitted = 0
    draining = False                 # training over: probe then stop
    probe_left = 0

    def refill():
        nonlocal upcoming
        if not upcoming:
            want = min(64, n_target - admitted) if n_target else 64
            if want > 0:
                upcoming = stream.take(want)[::-1]

    while True:
        t = now()
        # 1) admit everything that has "arrived" by the wall clock —
        #    or immediately during the final drain probe (those are
        #    re-stamped to arrive "now" so their latency is pure
        #    service time, not a fictional negative wait)
        refill()
        t_admit0 = tracer.now()
        n_before = admitted
        while upcoming and (draining or upcoming[-1][0] <= t):
            if n_target is not None and admitted >= n_target:
                break
            if draining:
                if probe_left <= 0:
                    break
                probe_left -= 1
            _, req = upcoming.pop()
            if draining:
                req.t_arrival = t
            req.t_admit = t
            if not queue.offer(req) and tracer.enabled:
                tracer.event("serve:shed", id=req.id,
                             depth=len(queue))
            admitted += 1
            refill()
        if tracer.enabled and admitted > n_before:
            tracer.add_span("serve:admit", t_admit0,
                            n=admitted - n_before)
        # 2) hot-swap between batches: a batch in flight is never torn
        replica.maybe_swap(bus, now=t)
        # 3) form + score (only once a first snapshot is installed —
        #    until then arrivals just queue up, shedding on overflow)
        no_more = ((n_target is not None and admitted >= n_target)
                   or (draining and probe_left <= 0))
        t_form0 = tracer.now()
        batch = (batcher.form(queue, t, flush=no_more)
                 if replica.ready else [])
        if batch:
            if tracer.enabled:
                tracer.add_span("serve:batch_form", t_form0,
                                n=len(batch))
            t_score0 = tracer.now()
            _score_batch(replica, batch, now)
            if tracer.enabled:
                tracer.add_span("serve:score", t_score0, n=len(batch),
                                version=replica.version)
            done.extend(batch)
            continue
        # 4) termination — serve-only stops once every generated
        #    request was ADMITTED-or-shed and the queue is drained (a
        #    shed request completes by rejection; waiting for it to be
        #    scored would spin forever)
        if (n_target is not None and admitted >= n_target
                and len(queue) == 0):
            break
        if producer_done is not None and not draining and producer_done():
            draining = True
            replica.drain(bus, now=now())
            probe_left = sconfig.final_probe
        elif draining and (len(queue) == 0 and probe_left <= 0
                           or not replica.ready):
            # probe served — or the trainer died before publishing
            # anything installable; either way nothing left to score
            break
        time.sleep(_IDLE_SLEEP_S)

    replica.drain(bus, now=now())
    if tracer.enabled:
        tracer.add_span("serve:loop", t_loop0, requests=len(done),
                        swaps=len(replica.swaps),
                        violations=replica.consistency_violations)
    return EngineResult(
        requests=done, swaps=list(replica.swaps),
        consistency_violations=replica.consistency_violations,
        queue_stats=dict(queue.stats), bus_stats=dict(bus.stats),
        timings={"serve_s": now()})


def _make_stream(source, sconfig: ServeConfig, num_classes):
    strat = traffic_registry.get(sconfig.traffic)
    return RequestStream(source, strat, rate=sconfig.rate,
                         num_classes=num_classes, seed=sconfig.seed)


def serve_static(params, cfg, source: data_lib.Source,
                 sconfig: ServeConfig, *, eval_mode="goodness",
                 impl="auto", tracer=obs_trace.NOOP) -> EngineResult:
    """Serve-only: a fixed params snapshot (version 0), no training
    underneath — the deterministic-replay and benchmark baseline mode."""
    n_layers = len(params["layers"])
    bus = WeightBus(n_layers, has_head="head" in params)
    bus.publish_all(0, params)
    replica = Replica(cfg.num_classes, max_batch=sconfig.max_batch,
                      eval_mode=eval_mode, impl=impl, tracer=tracer)
    stream = _make_stream(source, sconfig, cfg.num_classes)
    return run_serve(replica, bus, stream, sconfig, tracer=tracer)


def train_while_serve(executor, sconfig: ServeConfig,
                      source: Optional[data_lib.Source] = None,
                      *, resume_from=None,
                      tracer=obs_trace.NOOP) -> EngineResult:
    """Run the executor with live publication and serve from the same
    bus concurrently. The training thread's result (or exception) rides
    back on the ``EngineResult``; a training crash stops the serve loop
    rather than hanging it.

    A traced combined run hands the ONE tracer to both drivers: the
    executor's task spans (recorded on the ``pff-train`` thread) and
    the serve loop's spans share a clock domain, so swap installs line
    up against the chapter-train tasks that published them. Note the
    default tracer blocks per task (``block_tasks=True``), which slows
    training and shifts the serve timeline — pass
    ``Tracer(block_tasks=False)`` to observe serving behavior with
    training overlap intact."""
    bus = WeightBus(executor.n_layers, has_head=executor.has_head)
    replica = Replica(executor.cfg.num_classes,
                      max_batch=sconfig.max_batch,
                      eval_mode=executor.good.eval_mode(executor.cfg),
                      impl=executor.impl, tracer=tracer)
    if source is None:
        source = data_lib.source_of(executor.task)
    stream = _make_stream(source, sconfig, executor.cfg.num_classes)

    box = {}

    def trainer():
        t0 = time.perf_counter()
        try:
            box["result"] = executor.run(
                publish=bus, resume_from=resume_from,
                trace=tracer if tracer.enabled else None)
        except BaseException as e:              # surfaced to the caller
            box["error"] = e
        box["train_s"] = time.perf_counter() - t0

    th = threading.Thread(target=trainer, name="pff-train", daemon=True)
    th.start()
    out = run_serve(replica, bus, stream, sconfig,
                    producer_done=lambda: not th.is_alive(),
                    tracer=tracer)
    th.join()
    out.exec_result = box.get("result")
    out.train_error = box.get("error")
    out.timings["train_s"] = box.get("train_s", 0.0)
    if out.train_error is not None:
        raise out.train_error
    return out


# ---------------------------------------------------------------------------
# SLO summary (the ``.slo`` stats block on api.ServeResult)
# ---------------------------------------------------------------------------

def summarize(res: EngineResult) -> dict:
    """p50/p99 latency, throughput, shed rate, swap/staleness stats and
    the consistency counter — one dict, JSON-ready."""
    lats = np.asarray([r.latency for r in res.requests
                       if r.latency is not None])
    stale = np.asarray([s["staleness_s"] for s in res.swaps])
    serve_s = max(res.timings.get("serve_s", 0.0), 1e-9)
    n = len(res.requests)
    acc_reqs = [r for r in res.requests if r.pred is not None]
    return {
        "requests": n,
        "throughput_rps": n / serve_s,
        "latency_p50_ms": float(np.percentile(lats, 50)) * 1e3 if n else None,
        "latency_p99_ms": float(np.percentile(lats, 99)) * 1e3 if n else None,
        "latency_mean_ms": float(lats.mean()) * 1e3 if n else None,
        "accuracy": (float(np.mean([r.pred == r.label for r in acc_reqs]))
                     if acc_reqs else None),
        "accepted": res.queue_stats["accepted"],
        "rejected": res.queue_stats["rejected"],
        "shed_rate": (res.queue_stats["rejected"]
                      / max(res.queue_stats["accepted"]
                            + res.queue_stats["rejected"], 1)),
        "queue_depth_peak": res.queue_stats["depth_peak"],
        "swaps": len(res.swaps),
        "staleness_mean_s": float(stale.mean()) if len(stale) else None,
        "staleness_max_s": float(stale.max()) if len(stale) else None,
        "consistency_violations": res.consistency_violations,
    }


def accuracy_by_version(res: EngineResult) -> dict:
    """version -> (n_requests, accuracy): the accuracy-vs-time curve
    keyed by the snapshot that scored each window."""
    by_v = {}
    for r in res.requests:
        if r.pred is None:
            continue
        by_v.setdefault(r.version, []).append(r.pred == r.label)
    return {int(v): {"n": len(ok), "accuracy": float(np.mean(ok))}
            for v, ok in sorted(by_v.items())}
