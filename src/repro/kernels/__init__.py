"""TPU Pallas kernels for the compute hot-spots.

  ff_dense        — the FF-MLP hot loop: fused matmul -> ReLU -> goodness
                    (one pass computes the layer output AND the per-row
                    sum-of-squares the FF loss needs).
  ff_dense_vjp    — custom_vjp around ff_dense with a fused Pallas
                    backward kernel (dw/db/dx from resident tiles), so
                    jax.grad of the FF objective stays on the fused path.
  flash_attention — blockwise online-softmax attention (GQA / causal /
                    sliding-window) for the transformer archs.
  mamba2_ssd      — chunked SSD dual-form scan (intra-chunk quadratic +
                    carried state) for Mamba-2.

Each kernel ships as <name>.py (pl.pallas_call + BlockSpec), ops.py
(jit'd dispatch wrapper), ref.py (pure-jnp oracle). The FF-MLP model
code now calls the fused path for real: ``repro.core.ff_mlp`` trains and
predicts through ``ops.ff_dense`` with a config-driven
``kernel_impl: auto | pallas | ref`` switch (auto = Pallas on TPU,
oracle on CPU; Pallas runs under interpret=True off-TPU). The kernels
are validated against the oracles in tests/ and gated to <= 1e-4 by
``benchmarks/run.py``.
"""
