"""Pure-jnp oracles for every kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ff_dense import NORM_EPS


def ff_dense_ref(x, w, b):
    y = jnp.maximum(
        jnp.dot(x, w, preferred_element_type=jnp.float32)
        + b.astype(jnp.float32)[None, :], 0.0)
    g = jnp.sum(y * y, axis=1)
    return y.astype(x.dtype), g


def ff_dense_norm_ref(x, w, b):
    """``ff_dense_ref`` with Hinton's inter-layer length normalization
    applied to y — the oracle for the Pallas kernel's fused norm
    epilogue. g stays the RAW pre-norm goodness. The divide composes
    the exact op sequence the pre-fusion hand-off ran outside the
    kernel (``y / (sqrt(g) + eps)``, with sum-then-sqrt matching
    ``jnp.linalg.norm``), so the sequential trainer's ref-path weight
    stream is bit-identical to what it was when the divide lived
    outside the kernel."""
    y, g = ff_dense_ref(x, w, b)
    return (y / (jnp.sqrt(g)[..., None] + NORM_EPS)).astype(x.dtype), g


def flash_attention_ref(q, k, v, *, causal=True, window=None):
    """q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd). Dense reference."""
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    qf = q.astype(jnp.float32) * hd ** -0.5
    qf = qf.reshape(B, Sq, KV, G, hd)
    s = jnp.einsum("bikgd,bjkd->bkgij", qf, k.astype(jnp.float32))
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    m = jnp.ones((Sq, Sk), bool)
    if causal:
        m &= qpos >= kpos
    if window is not None:
        m &= (qpos - kpos) < window
    s = jnp.where(m[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgij,bjkd->bikgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, hd).astype(q.dtype)


def mamba2_ssd_ref(xbar, dA, b, c, h0=None):
    """Sequential (token-by-token) SSD recurrence — the ground truth.

    xbar: (B, S, H, hd) = x * dt; dA: (B, S, H) = dt * A (negative);
    b, c: (B, S, N). Returns y: (B, S, H, hd), hT: (B, H, hd, N).
    """
    B, S, H, hd = xbar.shape
    N = b.shape[-1]
    f32 = jnp.float32

    def step(h, inp):
        xb_t, dA_t, b_t, c_t = inp
        h = h * jnp.exp(dA_t)[..., None, None] + jnp.einsum(
            "bhd,bn->bhdn", xb_t, b_t)
        y = jnp.einsum("bn,bhdn->bhd", c_t, h)
        return h, y

    h0 = jnp.zeros((B, H, hd, N), f32) if h0 is None else h0.astype(f32)
    hT, ys = jax.lax.scan(
        step, h0,
        (xbar.astype(f32).transpose(1, 0, 2, 3),
         dA.astype(f32).transpose(1, 0, 2),
         b.astype(f32).transpose(1, 0, 2),
         c.astype(f32).transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2, 3), hT
