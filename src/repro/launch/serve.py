"""Serving launcher: prefill a batch of prompts, then batched greedy
decode against the KV caches. CPU-scale demo of the serve path the
decode dry-runs lower at production shapes.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
      --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import data as data_lib
from repro.configs import get_config
from repro.models import transformer


def serve(cfg, *, batch, prompt_len, gen, seed=0, greedy=True):
    key = jax.random.PRNGKey(seed)
    params = transformer.init(key, cfg)
    prompts = jnp.asarray(next(iter(data_lib.lm_batches(
        cfg.vocab, batch, prompt_len - 1, 1, seed))))

    aux = None
    if cfg.enc_dec:
        aux = jax.random.normal(key, (batch, cfg.enc_seq, cfg.d_model),
                                cfg.dtype)
    elif cfg.vision_tokens:
        aux = jax.random.normal(key, (batch, cfg.vision_tokens,
                                      cfg.d_model), cfg.dtype)

    max_len = prompt_len + gen
    prefill = jax.jit(lambda p, t, a: transformer.prefill(
        p, cfg, t, aux=a, max_len=max_len, last_only=True))
    step = jax.jit(lambda p, c, t, pos: transformer.serve_step(
        p, cfg, c, t, pos))

    t0 = time.time()
    logits, caches = prefill(params, prompts, aux)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    tok = jnp.argmax(logits, axis=-1)              # (B,)
    out = [tok]
    t1 = time.time()
    for i in range(gen - 1):
        logits, caches = step(params, caches, tok, prompt_len + i)
        tok = (jnp.argmax(logits, axis=-1) if greedy
               else jax.random.categorical(
                   jax.random.fold_in(key, i), logits))
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t1
    gen_tokens = jnp.stack(out, axis=1)
    return {
        "generated": gen_tokens,
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "decode_tok_per_s": batch * (gen - 1) / max(t_decode, 1e-9),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    res = serve(cfg, batch=args.batch, prompt_len=args.prompt_len,
                gen=args.gen, seed=args.seed)
    print(f"prefill {res['prefill_s']:.2f}s  decode {res['decode_s']:.2f}s"
          f"  ({res['decode_tok_per_s']:.1f} tok/s)")
    print("first generated rows:", res["generated"][:2, :12])


if __name__ == "__main__":
    main()
