"""Unit tests for the FF primitives (repro.core.ff)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ff


def test_goodness_values():
    y = jnp.asarray([[1.0, 2.0, 2.0], [0.0, 0.0, 0.0]])
    np.testing.assert_allclose(ff.goodness(y), [9.0, 0.0])
    np.testing.assert_allclose(ff.mean_goodness(y), [3.0, 0.0])


def test_ff_loss_direction():
    """Loss must fall as pos goodness rises and neg goodness falls."""
    theta = 2.0
    base = ff.ff_loss(jnp.asarray(2.0), jnp.asarray(2.0), theta)
    better = ff.ff_loss(jnp.asarray(4.0), jnp.asarray(0.5), theta)
    worse = ff.ff_loss(jnp.asarray(0.5), jnp.asarray(4.0), theta)
    assert better < base < worse


def test_ff_loss_masked_matches_split():
    g = jnp.asarray([3.0, 1.0, 0.5, 2.5])
    is_pos = jnp.asarray([1.0, 1.0, 0.0, 0.0])
    masked = ff.ff_loss_masked(g, is_pos, 2.0)
    # masked averages over all 4 samples; the pairwise form averages each
    # half separately -> exactly 2x the masked value
    split = 0.5 * (ff.ff_loss(g[0], g[2], 2.0) + ff.ff_loss(g[1], g[3], 2.0))
    np.testing.assert_allclose(2 * masked, split, rtol=1e-6)


def test_overlay_label_replaces_first_pixels():
    x = jnp.ones((3, 20)) * 0.5
    y = jnp.asarray([0, 3, 9])
    out = ff.overlay_label(x, y, 10)
    assert out.shape == (3, 20)
    np.testing.assert_allclose(out[0, :10],
                               jax.nn.one_hot(0, 10))
    np.testing.assert_allclose(out[1, :10], jax.nn.one_hot(3, 10))
    np.testing.assert_allclose(out[:, 10:], 0.5)


def test_overlay_neutral():
    x = jnp.ones((2, 15))
    out = ff.overlay_neutral(x, 10)
    np.testing.assert_allclose(out[:, :10], 0.1)


def test_random_wrong_labels_never_correct():
    key = jax.random.PRNGKey(1)
    y = jnp.arange(10).repeat(50)
    wrong = ff.random_wrong_labels(key, y, 10)
    assert not bool(jnp.any(wrong == y))
    assert bool(jnp.all((wrong >= 0) & (wrong < 10)))


def test_adaptive_wrong_labels_masks_true_class():
    scores = jnp.asarray([[9.0, 5.0, 1.0], [1.0, 9.0, 5.0]])
    y = jnp.asarray([0, 1])
    wrong = ff.adaptive_wrong_labels(scores, y)
    # true label masked -> picks the runner-up
    np.testing.assert_array_equal(wrong, [1, 2])


def test_adaptive_wrong_labels_sampling_never_correct():
    key = jax.random.PRNGKey(4)
    scores = jax.random.normal(key, (200, 10))
    y = jax.random.randint(key, (200,), 0, 10)
    wrong = ff.adaptive_wrong_labels(scores, y, key=key)
    assert not bool(jnp.any(wrong == y))


def test_adaptive_wrong_labels_moments_exclude_true_column():
    """Regression: the z-score moments must come from the WRONG-label
    columns only. The old code normalized by the full row (true label
    included), so a huge true-label score flattened the distribution
    over wrong labels — and changing ONLY the true label's score changed
    which negatives were sampled."""
    key = jax.random.PRNGKey(0)
    y = jnp.zeros((4096,), jnp.int32)
    base = jnp.tile(jnp.asarray([[10.0, 1.0, 2.0]]), (4096, 1))
    spiked = base.at[:, 0].set(1000.0)       # true-label column only
    lab_base = ff.adaptive_wrong_labels(base, y, key=key)
    lab_spiked = ff.adaptive_wrong_labels(spiked, y, key=key)
    # invariance: the true-label magnitude is not part of the moments
    np.testing.assert_array_equal(lab_base, lab_spiked)
    # hand-computed distribution: wrong columns {1.0, 2.0} -> mu=1.5,
    # sd=0.5 -> z = (-1, +1) -> P(2)/P(1) = e^2 ~ 7.4. The old full-row
    # moments gave z-diff ~ 0.25 -> ratio ~ 1.28 (nearly uniform).
    counts = jnp.bincount(lab_base, length=3)
    assert int(counts[0]) == 0               # true label masked
    ratio = float(counts[2]) / float(counts[1])
    assert 5.0 < ratio < 11.0, ratio


def test_corrupt_tokens_in_vocab_and_different():
    key = jax.random.PRNGKey(2)
    tokens = jax.random.randint(key, (8, 64), 0, 100)
    neg = ff.corrupt_tokens(key, tokens, 100)
    assert neg.shape == tokens.shape
    assert bool(jnp.all((neg >= 0) & (neg < 100)))
    # at least some positions corrupted across the batch
    assert int(jnp.sum(neg != tokens)) > 10


def test_adaptive_corrupt_tokens_shapes():
    key = jax.random.PRNGKey(3)
    tokens = jax.random.randint(key, (4, 32), 0, 50)
    logits = jax.random.normal(key, (4, 32, 50))
    neg = ff.adaptive_corrupt_tokens(key, tokens, logits)
    assert neg.shape == tokens.shape
    assert bool(jnp.all((neg >= 0) & (neg < 50)))


def test_peer_norm_zero_when_uniform():
    y = jnp.ones((16, 8))
    np.testing.assert_allclose(ff.peer_norm_loss(y), 0.0, atol=1e-7)
