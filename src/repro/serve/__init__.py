"""Train-while-serving: a continuous-batching goodness-classifier
serving subsystem with live per-layer weight hot-swap (ROADMAP item 2).

FF's layer-local updates are the whole reason this exists: with no
global backward pass, a freshly-trained layer k is immediately a valid
component of the serving model — the executor publishes each layer the
moment its chapter-train task completes (`PFFExecutor.run(publish=...)`)
and a serving replica swaps whole consistent snapshots in between
request batches, mid-training-run.

Layout (each module is one moving part):

- ``traffic``  — deterministic open-loop request generators behind a
  registry (uniform / zipf / bursty), seeded with ``data.py``'s
  per-(seed, chunk) idiom so any run replays bit-identically.
- ``queue``    — bounded admission queue (accept or shed, never block).
- ``batcher``  — continuous batch former (max-batch / max-wait knobs).
- ``bus``      — ``WeightBus``: the publication channel between the
  training executor and serving replicas; assembles per-layer
  publications into fully-consistent versioned snapshots.
- ``replica``  — scoring replica: installs snapshots monotonically with
  a version-vector check, scores batches through the fused
  ``ops.ff_dense`` path at one fixed jit shape.
- ``engine``   — the serve loop + the combined train-while-serve
  driver. ``repro.api.serve()`` is the supported entry point.
"""
from repro.serve.batcher import Batcher                       # noqa: F401
from repro.serve.bus import WeightBus                         # noqa: F401
from repro.serve.engine import (                              # noqa: F401
    ServeConfig, run_serve, train_while_serve)
from repro.serve.queue import AdmissionQueue, Request         # noqa: F401
from repro.serve.replica import Replica                       # noqa: F401
from repro.serve.traffic import (                             # noqa: F401
    RequestStream, TrafficStrategy, register_traffic, traffic)
