"""Gradient correctness of the fused FF custom_vjp (deliverable of the
hot-loop PR): the Pallas backward kernel vs jax.grad through the jnp
oracle, and ref-vs-pallas weight-stream equality of the chapter trainer.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.core import ff, ff_mlp
from repro.kernels import ref
from repro.kernels.ff_dense_vjp import ff_dense_vjp


def _stacked_ff_loss(apply_fn):
    """Fused pos/neg FF loss over a stacked (2B, K) batch, built on
    either the custom_vjp kernel or the oracle."""
    def loss(lp, xb, theta, peer_w):
        y, g = apply_fn(xb, lp["w"], lp["b"])
        g = g / y.shape[-1]
        half = xb.shape[0] // 2
        out = ff.ff_loss(g[:half], g[half:], theta)
        return out + peer_w * ff.peer_norm_loss(y[:half])
    return loss


_FUSED = _stacked_ff_loss(lambda x, w, b: ff_dense_vjp(x, w, b, True))
_ORACLE = _stacked_ff_loss(ref.ff_dense_ref)


@pytest.mark.parametrize("M,K,N", [(100, 333, 257), (64, 784, 512),
                                   (100, 784, 2000), (16, 64, 64)])
@pytest.mark.parametrize("peer_w", [0.0, 0.3])
def test_fused_grad_matches_oracle(M, K, N, peer_w, key):
    """Non-tile-aligned shapes exercise the padded backward path; the
    peer term exercises the dy cotangent, the FF loss the dg one."""
    kx, kw = jax.random.split(jax.random.fold_in(key, M * N + K))
    x = jax.random.normal(kx, (M, K), jnp.float32)
    lp = {"w": jax.random.normal(kw, (K, N), jnp.float32) * K ** -0.5,
          "b": jnp.full((N,), 0.1, jnp.float32)}
    gf, gxf = jax.grad(_FUSED, argnums=(0, 1))(lp, x, 2.0, peer_w)
    gr, gxr = jax.grad(_ORACLE, argnums=(0, 1))(lp, x, 2.0, peer_w)
    np.testing.assert_allclose(gf["w"], gr["w"], rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(gf["b"], gr["b"], rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(gxf, gxr, rtol=1e-4, atol=1e-6)


def test_fused_value_matches_oracle(key):
    x = jax.random.normal(key, (100, 333), jnp.float32)
    w = jax.random.normal(key, (333, 257), jnp.float32) * 333 ** -0.5
    b = jnp.full((257,), 0.05, jnp.float32)
    for peer_w in (0.0, 0.3):
        lf = _FUSED({"w": w, "b": b}, x, 2.0, peer_w)
        lr = _ORACLE({"w": w, "b": b}, x, 2.0, peer_w)
        np.testing.assert_allclose(lf, lr, rtol=1e-6, atol=1e-6)


def _run_chapter(impl, key, K, N, n, batch, epochs):
    kx, kn, kw, kt = jax.random.split(key, 4)
    # fresh buffers per run: the chapter trainer donates lp/opt
    x_pos = jax.random.normal(kx, (n, K), jnp.float32)
    x_neg = jax.random.normal(kn, (n, K), jnp.float32)
    lp = {"w": jax.random.normal(kw, (K, N), jnp.float32) * K ** -0.5,
          "b": jnp.zeros((N,), jnp.float32)}
    opt = optim.adam_init(lp)
    lrs = jnp.full((epochs,), 0.01, jnp.float32)
    stream = []
    for chapter in range(2):
        lp, opt = ff_mlp.train_layer_chapter(
            lp, opt, x_pos, x_neg, lrs, jax.random.fold_in(kt, chapter),
            batch=batch, epochs=epochs, theta=2.0, peer_w=0.0, impl=impl)
        stream.append(jax.tree.map(np.asarray, lp))
    return stream


def test_train_layer_chapter_ref_vs_pallas_weight_stream(key):
    """kernel_impl=ref and kernel_impl=pallas (interpret) must produce
    the same weight stream to <= 1e-4 max-abs across chapters."""
    K, N = 333, 257          # deliberately not tile-aligned
    ref_stream = _run_chapter("ref", key, K, N, n=256, batch=64, epochs=2)
    pal_stream = _run_chapter("pallas", key, K, N, n=256, batch=64,
                              epochs=2)
    for lr_, lp_ in zip(ref_stream, pal_stream):
        for name in ("w", "b"):
            max_err = float(np.abs(lr_[name] - lp_[name]).max())
            assert max_err <= 1e-4, (name, max_err)
