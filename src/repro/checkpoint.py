"""Checkpointing: pytree <-> .npz with path-string keys.

Handles the framework's param/optimizer pytrees (nested dicts/tuples of
arrays). Restore requires a template pytree (for structure + dtypes),
which is how the launcher resumes: init abstract params, then load.

Writes are atomic (tmp file + ``os.replace``), so a reader never sees a
half-written archive — the property the PFF executor's chapter-granular
manifests (``repro.core.pff_exec``) rely on to survive a hard kill
between chapters. Those manifests also use the two extension points
here: ``meta=`` (a JSON-serializable dict riding inside the archive,
e.g. the completed chapter + schedule fingerprint) and ``strict=``
restore (error on archive keys the template did not consume — a wrong
or stale manifest fails loudly instead of silently dropping state).

Both entry points take ``tracer=`` (an ``obs.trace`` tracer; default
the no-op singleton): a traced save/restore records one
``checkpoint:save`` / ``checkpoint:restore`` span covering the full
device->host drain + serialization (the per-chapter overhead
``BENCH_pff_faults.json`` measures, now visible on the timeline).
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import trace as obs_trace

# reserved archive keys (not pytree leaves)
_STEP_KEY = "__step__"
_META_KEY = "__meta__"


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or arr.dtype.name == "bfloat16":
            # np.savez can't persist ml_dtypes — upcast (lossless f32)
            arr = np.asarray(jnp.asarray(leaf, jnp.float32))
        flat[key] = arr
    return flat


def save(path, tree, step=None, meta=None, tracer=obs_trace.NOOP):
    """Atomically persist ``tree``; optionally a ``step`` int and a
    JSON-serializable ``meta`` dict (read back via ``restore(...,
    with_meta=True)``)."""
    t0 = tracer.now()
    flat = _flatten(tree)
    if _STEP_KEY in flat or _META_KEY in flat:
        raise ValueError(f"tree uses reserved key {_STEP_KEY}/{_META_KEY}")
    if step is not None:
        flat[_STEP_KEY] = np.asarray(step)
    if meta is not None:
        # json.dumps raises on non-serializable meta — fail at save
        # time, not at restore time
        flat[_META_KEY] = np.asarray(json.dumps(meta))
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(tmp, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp, path)
    if tracer.enabled:
        tracer.add_span("checkpoint:save", t0,
                        path=os.path.basename(path), step=step,
                        bytes=os.path.getsize(path))


def restore(path, template, *, strict=False, with_meta=False,
            tracer=obs_trace.NOOP):
    """Returns ``(tree_like_template, step or None)`` — or ``(tree,
    step, meta or None)`` with ``with_meta=True``.

    strict=True: raise if the archive holds keys the template did not
    consume (default False keeps the historical lenient behavior of
    ignoring extras — fine for partial restores, wrong for manifests).
    """
    t0 = tracer.now()
    with np.load(path) as z:
        data = {k: z[k] for k in z.files}
    step = data.pop(_STEP_KEY, None)
    meta = data.pop(_META_KEY, None)
    meta = json.loads(meta.item()) if meta is not None else None
    leaves_p = jax.tree_util.tree_flatten_with_path(template)
    paths, treedef = leaves_p[0], leaves_p[1]
    out = []
    consumed = set()
    for path_, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_)
        if key not in data:
            raise KeyError(f"checkpoint missing {key}")
        consumed.add(key)
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        # two-step conversion: numpy can't cast ml_dtypes (bf16) directly
        out.append(jnp.asarray(arr).astype(leaf.dtype))
    if strict:
        extra = sorted(set(data) - consumed)
        if extra:
            raise ValueError(
                f"checkpoint holds {len(extra)} key(s) the template did "
                f"not consume: {', '.join(extra[:5])}"
                + ("..." if len(extra) > 5 else ""))
    tree = jax.tree_util.tree_unflatten(treedef, out)
    step = int(step) if step is not None else None
    if tracer.enabled:
        tracer.add_span("checkpoint:restore", t0,
                        path=os.path.basename(path), step=step)
    return (tree, step, meta) if with_meta else (tree, step)
