"""Blockwise online-softmax attention (flash-style) Pallas kernel.

Supports GQA (H = G * KV query heads share KV heads), causal masking and
sliding-window. Layout decisions for TPU:

  * grid = (B, H, nq, nk) with nk innermost — for a fixed (b, h, iq) the
    kv blocks stream through VMEM while the (bq, hd) accumulator and the
    (bq,) running max / sum live in VMEM scratch across nk steps.
  * q is loaded once per (b, h, iq) and multiplied by 1/sqrt(hd) in f32.
  * the MXU sees (bq, hd) x (hd, bk) for scores and (bq, bk) x (bk, hd)
    for the PV product; both tiles are 128-aligned by default.
  * causal + window masking is done in-kernel via block-position iota;
    fully-masked blocks still execute (interpret-mode correctness first;
    on real TPU the index_map would skip them — noted in DESIGN.md).

The KV-head index for GQA is derived in the index_map: kv = h // G.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            causal, window, bq, bk, nk, scale):
    ik = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr[...], NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr[...])
        acc_scr[...] = jnp.zeros_like(acc_scr[...])

    q = q_ref[0, :, 0, :].astype(jnp.float32) * scale      # (bq, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)              # (bk, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq",
                                             "bk", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, bq=128, bk=128,
                    interpret=True):
    """q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd) -> (B, Sq, H, hd)."""
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    nq, nk = Sq // bq, Sk // bk

    kernel = functools.partial(
        _kernel, causal=causal, window=window, bq=bq, bk=bk, nk=nk,
        scale=hd ** -0.5)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, 1, hd), lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, bk, 1, hd),
                         lambda b, h, iq, ik: (b, ik, h // G, 0)),
            pl.BlockSpec((1, bk, 1, hd),
                         lambda b, h, iq, ik: (b, ik, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, hd),
                               lambda b, h, iq, ik: (b, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),          # running max
            pltpu.VMEM((bq,), jnp.float32),          # running sum
            pltpu.VMEM((bq, hd), jnp.float32),       # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
