"""Kernel impl registry: named implementations per op, with platform
predicates — the dispatch substrate behind ``ops.ff_dense`` /
``ops.flash_attention`` / ``ops.mamba2_ssd``.

This is the same pattern ``core.strategies`` established for
negatives/goodness/classifier, applied one level down: instead of a
string-``if`` chain per op ("TPU -> Pallas, else oracle"), each op owns
a small registry of named impls, and new backends (a Pallas-Triton GPU
lowering, a hand-written Mosaic variant, a vendor library call) are
REGISTRATIONS, not patches to the dispatcher:

    from repro.kernels import registry
    registry.register_kernel_impl(
        "ff_dense", "triton", my_fn,
        preferred=lambda platform: platform == "gpu", tunable=True)
    # ops.ff_dense(impl="triton") and --kernel-impl triton now work,
    # and impl="auto" prefers it on GPU.

Impl callable contracts (keyword-only after the operands):

  ff_dense:        fn(x, w, b, *, norm, interpret, blocks) -> (y, g)
  flash_attention: fn(q, k, v, *, causal, window, interpret) -> o
  mamba2_ssd:      fn(xbar, dA, b, c, *, chunk, interpret) -> (y, hT)

``interpret`` is True off-TPU (Pallas interpret mode); non-Pallas impls
ignore it. ``blocks`` is an autotuned ``(bm, bn, bk)`` tuple or None
(see ``kernels.autotune``); impls without tunable block shapes ignore
it. Every registry carries a ``fallback`` impl (the jnp oracle) that
``"auto"`` resolves to when no registered impl prefers the current
platform — the nebullvm-style graceful degradation: "auto" always means
a CORRECT impl, and the tuning table (consulted by ``ops``, not here)
upgrades it to the fastest MEASURED one.

Resolution order for ``"auto"``: registration order, first impl whose
``preferred(platform)`` is True, else the fallback. Unknown impl names
raise ``ValueError`` listing the registered choices (the same helpful
error for all three ops — previously only ``ff_dense`` had it).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from repro.kernels import ref
from repro.kernels.ff_dense_vjp import ff_dense_norm_vjp, ff_dense_vjp
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.mamba2_ssd import mamba2_ssd as _ssd_pallas


@dataclasses.dataclass(frozen=True)
class KernelImpl:
    """One named implementation of an op.

    preferred(platform) drives ``"auto"``: True means this impl is the
    platform's native fast path (e.g. Pallas on TPU). An impl can be
    available-but-not-preferred (Pallas runs anywhere via interpret
    mode, but "auto" only picks it on TPU).
    tunable: participates in the autotuner's block-shape sweep (its fn
    honors the ``blocks`` kwarg).
    """
    name: str
    fn: Callable
    preferred: Callable[[str], bool]
    tunable: bool = False


class KernelRegistry:
    """name -> KernelImpl for one op, with ``"auto"`` resolution."""

    def __init__(self, op: str, fallback: Optional[str] = None):
        self.op = op
        self.fallback = fallback
        self._entries = {}

    def register(self, name, fn, *, preferred=None, tunable=False,
                 overwrite=False):
        if name == "auto":
            raise ValueError(f"'auto' is the {self.op} resolver keyword, "
                             "not a registrable impl name")
        if not overwrite and name in self._entries:
            raise ValueError(
                f"{self.op} impl {name!r} already registered "
                "(pass overwrite=True to replace)")
        if preferred is None:
            preferred = lambda platform: False          # noqa: E731
        impl = KernelImpl(name, fn, preferred, tunable)
        self._entries[name] = impl
        return impl

    def unregister(self, name):
        """Remove an impl (no-op if absent) — tests and experiments."""
        self._entries.pop(name, None)

    def get(self, name) -> KernelImpl:
        try:
            return self._entries[name]
        except KeyError:
            raise ValueError(
                f"unknown {self.op} impl {name!r}; expected one of "
                f"{' | '.join(self.choices())}") from None

    def resolve(self, platform) -> KernelImpl:
        """``"auto"``: first registered impl preferring ``platform``,
        else the fallback oracle."""
        for impl in self._entries.values():
            if impl.preferred(platform):
                return impl
        return self.get(self.fallback)

    def names(self):
        return tuple(sorted(self._entries))

    def choices(self):
        """Valid ``impl=`` strings — what CLIs and error messages show."""
        return ("auto",) + self.names()

    def tunable_names(self):
        return tuple(n for n in self.names() if self._entries[n].tunable)

    def __contains__(self, name):
        return name in self._entries

    def __iter__(self):
        return iter(self.names())


ff_dense = KernelRegistry("ff_dense", fallback="ref")
flash_attention = KernelRegistry("flash_attention", fallback="ref")
mamba2_ssd = KernelRegistry("mamba2_ssd", fallback="ref")

REGISTRIES = {
    "ff_dense": ff_dense,
    "flash_attention": flash_attention,
    "mamba2_ssd": mamba2_ssd,
}


def registry(op) -> KernelRegistry:
    try:
        return REGISTRIES[op]
    except KeyError:
        raise ValueError(f"unknown op {op!r}; expected one of "
                         f"{' | '.join(sorted(REGISTRIES))}") from None


def register_kernel_impl(op, name, fn, *, preferred=None, tunable=False,
                         overwrite=False):
    """Public hook: plug a new kernel impl into an op's dispatch."""
    return registry(op).register(name, fn, preferred=preferred,
                                 tunable=tunable, overwrite=overwrite)


# ---------------------------------------------------------------------------
# Builtin impls. Registration order matters for "auto": the Pallas
# kernels are the TPU-preferred fast path, the jnp oracles the
# everywhere-fallback (and the autotuner's correctness reference).
# ---------------------------------------------------------------------------

def _on_tpu(platform):
    return platform == "tpu"


def _ff_dense_pallas(x, w, b, *, norm, interpret, blocks):
    fused = ff_dense_norm_vjp if norm else ff_dense_vjp
    return fused(x, w, b, interpret, blocks)


def _ff_dense_ref(x, w, b, *, norm, interpret, blocks):
    del interpret, blocks
    if norm:
        return ref.ff_dense_norm_ref(x, w, b)
    return ref.ff_dense_ref(x, w, b)


def _flash_attention_pallas(q, k, v, *, causal, window, interpret):
    return _flash_pallas(q, k, v, causal=causal, window=window,
                         interpret=interpret)


def _flash_attention_ref(q, k, v, *, causal, window, interpret):
    del interpret
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window)


def _mamba2_ssd_pallas(xbar, dA, b, c, *, chunk, interpret):
    return _ssd_pallas(xbar, dA, b, c, chunk=chunk, interpret=interpret)


def _mamba2_ssd_ref(xbar, dA, b, c, *, chunk, interpret):
    del chunk, interpret
    return ref.mamba2_ssd_ref(xbar, dA, b, c)


ff_dense.register("pallas", _ff_dense_pallas, preferred=_on_tpu,
                  tunable=True)
ff_dense.register("ref", _ff_dense_ref)
flash_attention.register("pallas", _flash_attention_pallas,
                         preferred=_on_tpu)
flash_attention.register("ref", _flash_attention_ref)
mamba2_ssd.register("pallas", _mamba2_ssd_pallas, preferred=_on_tpu)
mamba2_ssd.register("ref", _mamba2_ssd_ref)
