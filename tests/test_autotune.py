"""Kernel registry + autotuner: registry round-trip and custom-impl
registration (mirroring the api strategy-registry tests one level
down), tuning-table persistence (save -> load -> memo hit, byte-stable
ordering), candidate generation under the VMEM row-residency budget,
oracle-gate rejection, deterministic winners under an injected timer,
and the poisoned-table fallback paths.

The conftest autouse fixture points REPRO_TUNE_TABLE at a per-test tmp
file, so these tests never see (or pollute) a real ~/.cache table.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune, ops, ref, registry
from repro.kernels.ff_dense import VMEM_BUDGET_BYTES, vmem_block_bytes


def _fake_timer(times):
    """Deterministic injectable timer: label-keyed lookup with a
    default, never calls the thunk (so tests time nothing)."""
    def timer(thunk, label):
        del thunk
        for frag, t in times.items():
            if frag in label:
                return t
        return 1.0
    return timer


def _tune_once(shapes=((16, 64, 128),), norms=(False,), times=None,
               **kw):
    return autotune.tune_ff_dense(
        list(shapes), norms=norms, timer=_fake_timer(times or {}),
        save=True, verbose=False, **kw)


# ---------------------------------------------------------------------------
# Registry round-trip + custom impl registration (the strategy-registry
# contract, one level down)
# ---------------------------------------------------------------------------

def test_registry_round_trip_of_builtin_impl_names():
    for op, reg in registry.REGISTRIES.items():
        assert set(reg.names()) >= {"pallas", "ref"}
        assert reg.choices()[0] == "auto"
        for name in reg.names():
            assert reg.get(name).name == name
            assert name in reg
        assert list(iter(reg)) == sorted(reg.names())
        assert registry.registry(op) is reg


def test_registry_unknown_name_lists_choices():
    with pytest.raises(ValueError, match="pallas"):
        registry.ff_dense.get("does_not_exist")
    with pytest.raises(ValueError, match="flash_attention"):
        registry.flash_attention.get("nope")
    with pytest.raises(ValueError, match="unknown op"):
        registry.registry("not_an_op")


def test_registry_rejects_auto_as_impl_name():
    with pytest.raises(ValueError, match="auto"):
        registry.ff_dense.register("auto", lambda *a, **k: None)


def test_register_custom_ff_dense_impl(key):
    """A user-registered impl is reachable through ops.ff_dense(impl=)
    and shows up in the live FF_DENSE_IMPLS choices."""
    def shifted(x, w, b, *, norm, interpret, blocks):
        y, g = ref.ff_dense_ref(x, w, b)
        return y + 1.0, g

    registry.register_kernel_impl("ff_dense", "shifted", shifted)
    try:
        assert "shifted" in registry.ff_dense
        assert "shifted" in ops.FF_DENSE_IMPLS
        x = jax.random.normal(key, (8, 16))
        w = jax.random.normal(key, (16, 32)) * 0.1
        b = jnp.zeros((32,))
        y, _ = ops.ff_dense(x, w, b, impl="shifted")
        yr, _ = ref.ff_dense_ref(x, w, b)
        np.testing.assert_allclose(y, yr + 1.0, rtol=1e-6)
        with pytest.raises(ValueError, match="already registered"):
            registry.register_kernel_impl("ff_dense", "shifted", shifted)
        registry.register_kernel_impl("ff_dense", "shifted", shifted,
                                      overwrite=True)
    finally:
        registry.ff_dense.unregister("shifted")
    assert "shifted" not in registry.ff_dense
    assert "shifted" not in ops.FF_DENSE_IMPLS


def test_auto_resolution_prefers_platform_then_fallback():
    reg = registry.KernelRegistry("demo", fallback="ref")
    reg.register("fast", lambda: None,
                 preferred=lambda p: p == "tpu")
    reg.register("ref", lambda: None)
    assert reg.resolve("tpu").name == "fast"
    assert reg.resolve("cpu").name == "ref"


# ---------------------------------------------------------------------------
# Candidate generation
# ---------------------------------------------------------------------------

def test_candidate_blocks_clamped_aligned_and_within_budget():
    M, K, N = 48, 512, 384
    for norm in (False, True):
        grid = autotune.candidate_blocks(M, K, N, norm=norm)
        assert grid, "empty candidate grid for a modest shape"
        for bm, bn in grid:
            assert bm <= M
            assert bn % 128 == 0 or bn == N
            assert vmem_block_bytes(K, N, bm, bn, norm=norm) \
                <= VMEM_BUDGET_BYTES


def test_candidate_blocks_norm_respects_row_residency():
    """norm=True widens the y block to the whole (bm, N) row, so a
    shape whose row cannot fit must lose its biggest bm candidates."""
    # small K keeps the x/w blocks cheap, large N makes the norm path's
    # whole-row y block (bm x N) the binding constraint
    M, K, N = 256, 256, 8192
    plain = autotune.candidate_blocks(M, K, N, norm=False)
    normed = autotune.candidate_blocks(M, K, N, norm=True)
    assert set(normed) <= set(plain)
    assert max(bm for bm, _ in normed) < max(bm for bm, _ in plain)


# ---------------------------------------------------------------------------
# Table persistence + memoization
# ---------------------------------------------------------------------------

def test_table_round_trip_and_memo_hit():
    rows = _tune_once(times={"ref": 0.5, "bm=16": 0.1})
    assert rows and rows[0]["winner"] is not None
    path = autotune.table_path()
    assert os.path.exists(path)

    fresh = autotune.TuneTable.open(path)
    assert len(fresh) == 1
    assert fresh.entries == {r["key"]: r["winner"] for r in rows}

    autotune.invalidate_cache()
    loads0 = autotune.STATS["loads"]
    hits0 = autotune.STATS["memo_hits"]
    r = rows[0]
    first = autotune.lookup("ff_dense", r["M"], r["K"], r["N"],
                            jnp.float32, jax.default_backend())
    again = autotune.lookup("ff_dense", r["M"], r["K"], r["N"],
                            jnp.float32, jax.default_backend())
    assert first == again == fresh.entries[r["key"]]
    assert autotune.STATS["loads"] == loads0 + 1
    assert autotune.STATS["memo_hits"] == hits0 + 1


def test_table_save_is_byte_stable_across_insertion_order(tmp_path):
    e1 = {"impl": "ref", "time_s": 0.5, "err": 0.0, "grad_err": 0.0}
    e2 = {"impl": "pallas", "bm": 16, "bn": 128, "time_s": 0.1,
          "err": 1e-6, "grad_err": 1e-6}
    a = autotune.TuneTable(str(tmp_path / "a.json"))
    a.put("k1", dict(e1))
    a.put("k2", dict(e2))
    b = autotune.TuneTable(str(tmp_path / "b.json"))
    b.put("k2", dict(e2))
    b.put("k1", dict(e1))
    a.save()
    b.save()
    with open(a.path, "rb") as f1, open(b.path, "rb") as f2:
        assert f1.read() == f2.read()


def test_retune_with_same_inputs_leaves_file_bit_identical():
    _tune_once(times={"ref": 0.5})
    with open(autotune.table_path(), "rb") as f:
        before = f.read()
    _tune_once(times={"ref": 0.5})
    with open(autotune.table_path(), "rb") as f:
        assert f.read() == before


# ---------------------------------------------------------------------------
# Winner selection
# ---------------------------------------------------------------------------

def test_deterministic_winner_under_fake_timer():
    times = {"bm=16|bn=128": 0.01, "ref": 0.2}
    rows_a = _tune_once(times=times)
    autotune.invalidate_cache()
    rows_b = _tune_once(times=times)
    assert rows_a[0]["winner"] == rows_b[0]["winner"]
    w = rows_a[0]["winner"]
    assert w["impl"] == "pallas"
    assert (w["bm"], w["bn"]) == (16, 128)
    assert w["err"] <= autotune.ERR_GATE
    assert w["grad_err"] <= autotune.ERR_GATE


def test_candidate_rejected_on_oracle_error_breach(monkeypatch):
    """The fastest candidate must NOT win if it breaches the 1e-4 gate
    — fast-but-wrong never reaches the table."""
    bad = (16, 128, None)
    real_errors = autotune._candidate_errors

    def rigged(impl_name, blocks, data, oracle, *, norm, interpret):
        if blocks == bad:
            return 1.0, 1.0           # grossly wrong
        return real_errors(impl_name, blocks, data, oracle, norm=norm,
                           interpret=interpret)

    monkeypatch.setattr(autotune, "_candidate_errors", rigged)
    # the rigged candidate is also by far the fastest
    rows = _tune_once(times={"bm=16|bn=128": 1e-9, "ref": 0.2})
    w = rows[0]["winner"]
    assert w is not None
    assert not ("bm" in w and (w["bm"], w["bn"]) == (16, 128))
    breaches = [rj for rj in rows[0]["rejected"]
                if tuple(rj["blocks"] or ()) == bad[:2] + (None,)
                or rj["blocks"] == list(bad)]
    assert any("oracle error breach" in rj["reason"]
               for rj in rows[0]["rejected"])
    assert breaches or rows[0]["n_rejected"] >= 1


def test_untuned_bucket_warns_when_nothing_passes(monkeypatch):
    monkeypatch.setattr(autotune, "_candidate_errors",
                        lambda *a, **k: (1.0, 1.0))
    with pytest.warns(UserWarning, match="no candidate passed"):
        rows = _tune_once()
    assert rows[0]["winner"] is None
    assert len(autotune.TuneTable.open(autotune.table_path())) == 0


# ---------------------------------------------------------------------------
# ops integration: the table steers "auto" and blocks reach "pallas"
# ---------------------------------------------------------------------------

def _put_entry(key, entry):
    t = autotune.TuneTable.open()
    t.put(key, entry)
    t.save()


def test_lookup_steers_auto_to_table_winner(key):
    """A persisted winner redirects impl='auto' — observed through a
    sentinel impl with a distinctive output."""
    M, K, N = 8, 16, 32

    def sentinel(x, w, b, *, norm, interpret, blocks):
        y, g = ref.ff_dense_ref(x, w, b)
        return y + 7.0, g

    registry.register_kernel_impl("ff_dense", "sentinel", sentinel)
    try:
        _put_entry(
            autotune.key_for("ff_dense", M, K, N, jnp.float32,
                             jax.default_backend(), False),
            {"impl": "sentinel", "time_s": 0.1, "err": 0.0,
             "grad_err": 0.0})
        x = jax.random.normal(key, (M, K))
        w = jax.random.normal(key, (K, N)) * 0.1
        b = jnp.zeros((N,))
        y, _ = ops.ff_dense(x, w, b, impl="auto")
        yr, _ = ref.ff_dense_ref(x, w, b)
        np.testing.assert_allclose(y, yr + 7.0, rtol=1e-6)
        # other shape buckets miss the table -> registry default (ref
        # on CPU), no sentinel shift
        y2, _ = ops.ff_dense(x[:4], w, b, impl="auto")
        np.testing.assert_allclose(y2, ref.ff_dense_ref(x[:4], w, b)[0],
                                   rtol=1e-6)
    finally:
        registry.ff_dense.unregister("sentinel")
        autotune.invalidate_cache()


def test_tuned_blocks_reach_forced_pallas(key):
    """impl='pallas' consults the table for block shapes even when the
    recorded WINNER is another impl."""
    M, K, N = 16, 64, 128
    _put_entry(
        autotune.key_for("ff_dense", M, K, N, jnp.float32,
                         jax.default_backend(), False),
        {"impl": "ref", "time_s": 0.1, "err": 0.0, "grad_err": 0.0,
         "bm": 8, "bn": 128, "pallas_time_s": 0.2})
    seen = {}
    orig = registry.ff_dense.get("pallas").fn

    def spy(x, w, b, **kw):
        seen["blocks"] = kw["blocks"]
        return orig(x, w, b, **kw)

    registry.register_kernel_impl("ff_dense", "pallas", spy,
                                  tunable=True, overwrite=True)
    try:
        x = jax.random.normal(key, (M, K))
        w = jax.random.normal(key, (K, N)) * 0.1
        b = jnp.zeros((N,))
        y, g = ops.ff_dense(x, w, b, impl="pallas")
        assert seen["blocks"] == (8, 128, None)
        yr, gr = ref.ff_dense_ref(x, w, b)
        np.testing.assert_allclose(y, yr, rtol=1e-5, atol=1e-5)
    finally:
        registry.register_kernel_impl("ff_dense", "pallas", orig,
                                      tunable=True, overwrite=True)


# ---------------------------------------------------------------------------
# Poisoned-table fallbacks: warn and default, never crash
# ---------------------------------------------------------------------------

def _lookup_small():
    return autotune.lookup("ff_dense", 8, 16, 32, jnp.float32,
                           jax.default_backend())


def test_corrupt_json_file_warns_and_defaults(key):
    path = autotune.table_path()
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write("{ not json at all")
    with pytest.warns(UserWarning, match="poisoned kernel tuning table"):
        assert _lookup_small() is None
    # dispatch still works end-to-end on defaults
    x = jax.random.normal(key, (8, 16))
    w = jax.random.normal(key, (16, 32)) * 0.1
    b = jnp.zeros((32,))
    y, g = ops.ff_dense(x, w, b)
    assert bool(jnp.isfinite(y).all()) and bool(jnp.isfinite(g).all())


@pytest.mark.parametrize("entry", [
    {"impl": "pallas", "time_s": 0.1, "err": 0.0, "grad_err": 0.0,
     "bm": "sixteen", "bn": 128},                    # non-int block
    {"impl": "pallas", "time_s": 0.1, "err": 0.0, "grad_err": 0.0,
     "bm": 1 << 20, "bn": 1 << 20},                  # breaks residency
    {"impl": "pallas", "time_s": 0.1, "err": 0.0, "grad_err": 0.0},
    # ^ pallas winner without blocks
    {"impl": "not_registered", "time_s": 0.1, "err": 0.0,
     "grad_err": 0.0},                               # unknown impl
    {"time_s": 0.1},                                 # no impl at all
])
def test_poisoned_entry_warns_and_defaults(entry, key):
    _put_entry(autotune.key_for("ff_dense", 8, 16, 32, jnp.float32,
                                jax.default_backend(), False), entry)
    with pytest.warns(UserWarning, match="poisoned tuning-table entry"):
        assert _lookup_small() is None
    with pytest.warns(UserWarning, match="poisoned tuning-table entry"):
        x = jax.random.normal(key, (8, 16))
        w = jax.random.normal(key, (16, 32)) * 0.1
        b = jnp.zeros((32,))
        y, _ = ops.ff_dense(x, w, b, impl="auto")
    np.testing.assert_allclose(y, ref.ff_dense_ref(x, w, b)[0],
                               rtol=1e-6)


def test_key_for_is_stable_and_bucketed():
    k = autotune.key_for("ff_dense", 64, 128, 256, jnp.float32, "cpu",
                         True)
    assert k == "ff_dense|M=64|K=128|N=256|dtype=float32|platform=cpu|norm=1"
    assert k != autotune.key_for("ff_dense", 64, 128, 256, jnp.float32,
                                 "cpu", False)
    assert k != autotune.key_for("ff_dense", 64, 128, 256, jnp.bfloat16,
                                 "cpu", True)


def test_table_meta_documents_bit_exactness_policy():
    """The meta note is load-bearing documentation: it must pin the
    oracle-gate-not-bit-exactness policy and the matrix's ref pin."""
    rows = _tune_once(times={"ref": 0.1})
    assert rows
    with open(autotune.table_path()) as f:
        raw = json.load(f)
    note = raw["meta"]["note"]
    assert "bit-exactness" in note and "ref" in note
    assert raw["meta"]["err_gate"] == autotune.ERR_GATE
