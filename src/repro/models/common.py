"""Shared numerics: norms, RoPE, initializers, activations."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(cfg):
    return jnp.dtype(cfg.dtype)


def rms_norm(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def rms_normalize(x, eps=1e-6):
    """Scale-free RMS normalization (used for FF goodness locality)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def activation(name):
    return {
        "silu": jax.nn.silu,
        "gelu": lambda x: jax.nn.gelu(x, approximate=True),
        "relu": jax.nn.relu,
    }[name]


def softcap(x, cap):
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim, theta):
    half = head_dim // 2
    return 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))


def apply_rope(x, positions, theta):
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    inv = jnp.asarray(rope_freqs(hd, theta))                   # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv       # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]                           # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Initializers (all take an explicit key; scaled-normal like llama)
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[0]
    std = fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def zeros_init(_key, shape, dtype):
    return jnp.zeros(shape, dtype)


def stack_init(key, repeat, init_fn):
    """Initialize `repeat` copies of a param tree, stacked on axis 0."""
    keys = jax.random.split(key, repeat)
    return jax.vmap(init_fn)(keys)
