"""Benchmark harness entry point: ``PYTHONPATH=src python -m benchmarks.run``.

Sections (one per paper table/figure + framework-level):
  1. paper tables 1-5 analogues (FF/PFF accuracy + schedule times)
  2. FF vs backprop on the synthetic LM (framework substrate)
  3. kernel validation sweep (Pallas vs oracle, interpret mode)
  4. roofline table from the dry-run records (if present)

``--full`` runs the bigger paper-table configuration; default is the
quick profile (~10 min on this CPU container).
"""
from __future__ import annotations

import sys
import time


def main(argv):
    full = "--full" in argv
    only = None
    for a in argv:
        if a.startswith("--only="):
            only = a.split("=", 1)[1]
    t0 = time.time()

    if only in (None, "tables"):
        print("\n##### 1. Paper tables 1-5 analogues #####")
        from benchmarks import paper_tables
        paper_tables.run_tables(quick=not full)

    if only in (None, "lm"):
        print("\n##### 2. FF vs backprop on the synthetic LM #####")
        from benchmarks import lm_ff
        lm_ff.run()

    if only in (None, "lm_schedules"):
        print("\n##### 2b. Joint-FF vs chapter-scheduled FF (paper's "
              "schedule on a transformer) #####")
        from benchmarks import lm_schedules
        lm_schedules.run()

    if only in (None, "lm_negatives"):
        print("\n##### 2c. LM negative-strategy ablation "
              "(random/fixed/adaptive corruption) #####")
        from benchmarks import lm_negatives
        lm_negatives.run()

    if only in (None, "kernels"):
        print("\n##### 3. Kernel validation (Pallas interpret vs oracle) "
              "#####")
        from benchmarks import kernels as kbench
        kbench.run()

    if only in (None, "roofline"):
        print("\n##### 4. Roofline (from dry-run records) #####")
        from benchmarks import roofline
        roofline.main()

    print(f"\nbenchmarks done in {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main(sys.argv[1:])
