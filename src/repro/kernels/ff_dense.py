"""Fused FF layer kernel: y = relu(x @ w + b), g = sum(y^2, axis=-1).

The Forward-Forward hot loop evaluates a dense layer AND its goodness for
both the positive and negative batch every step. Fusing the goodness
reduction into the matmul epilogue saves one full HBM round-trip of the
(M, N) activations — on TPU the (bm, bn) tile is reduced to a (bm,)
partial in VMEM right after the MXU matmul, while the tile is still hot.

Grid: (M/bm, N/bn), N innermost so the goodness partials for a row-block
accumulate across the j steps in the same VMEM scratch-free output block
(revisited blocks are legal because the TPU grid is executed
sequentially minor-to-major).

Tile defaults are MXU-aligned (128x128); K is streamed whole per tile —
for the paper's [784, 2000] layers x(bm, K) + w(K, bn) comfortably fit
VMEM (784*128*4 + 784*128*4 ~= 0.8 MB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, b_ref, y_ref, g_ref):
    j = pl.program_id(1)
    h = jnp.dot(x_ref[...], w_ref[...],
                preferred_element_type=jnp.float32)
    h = h + b_ref[...][None, :]
    y = jnp.maximum(h, 0.0)
    y_ref[...] = y.astype(y_ref.dtype)
    g_part = jnp.sum(y * y, axis=1)

    @pl.when(j == 0)
    def _init():
        g_ref[...] = g_part

    @pl.when(j != 0)
    def _acc():
        g_ref[...] = g_ref[...] + g_part


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def ff_dense(x, w, b, *, bm=128, bn=128, interpret=True):
    """x: (M, K), w: (K, N), b: (N,) -> (y (M, N), goodness (M,) f32)."""
    M, K = x.shape
    _, N = w.shape
    bm = min(bm, M)
    bn = min(bn, N)
    if M % bm or N % bn:          # pad to tile multiples
        Mp = -(-M // bm) * bm
        Np = -(-N // bn) * bn
        xp = jnp.pad(x, ((0, Mp - M), (0, 0)))
        wp = jnp.pad(w, ((0, 0), (0, Np - N)))
        bp = jnp.pad(b, (0, Np - N))
        y, g = ff_dense(xp, wp, bp, bm=bm, bn=bn, interpret=interpret)
        return y[:M, :N], g[:M]

    grid = (M // bm, N // bn)
    y, g = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, K), lambda i, j: (i, 0)),
            pl.BlockSpec((K, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, N), x.dtype),
            jax.ShapeDtypeStruct((M,), jnp.float32),
        ],
        interpret=interpret,
    )(x, w, b)
    return y, g
