"""Ring attention (context parallelism) vs the dense oracle.

Runs in a subprocess with 4 faked host devices (tests must not set
XLA_FLAGS in-process — the suite needs the real single device).
"""
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.models.ring_attention import ring_attention
    from repro.kernels import ref

    mesh = jax.make_mesh((4,), ("seq",))
    key = jax.random.PRNGKey(0)
    for B, S, H, KV, hd, causal in [(2, 128, 4, 2, 32, True),
                                    (1, 64, 4, 4, 16, False),
                                    (2, 256, 8, 1, 32, True)]:
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
        with mesh:
            o = ring_attention(q, k, v, mesh=mesh, axis="seq",
                               causal=causal)
        orf = ref.flash_attention_ref(q, k, v, causal=causal)
        err = float(jnp.abs(o - orf).max())
        assert err < 2e-5, (B, S, H, KV, hd, causal, err)
        print(f"ring B{B} S{S} H{H}/{KV} causal={causal}: err={err:.2e}")
    print("RING_OK")
""")


def test_ring_attention_matches_oracle():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env, timeout=300,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert "RING_OK" in r.stdout, r.stdout + r.stderr
