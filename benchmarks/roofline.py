"""Roofline table builder — reads the dry-run JSONs and prints/saves the
per-(arch x shape x mesh) three-term roofline analysis (deliverable g).

Also exports the single-kernel roofline helpers the autotuner's bench
gate uses (``benchmarks.kernels.run_tune``): an analytic min-time for
one fused ff_dense fwd+bwd step against nominal platform peaks, so
tuning wins are reported as %-of-roofline per shape, not just raw
seconds (the load-insensitive framing — raw seconds on this shared CPU
container are scheduling noise, and interpret-mode Pallas numbers are
not kernel numbers at all; the % column says how far from the machine's
ceiling the MEASURED winner is, whatever the machine).
"""
from __future__ import annotations

import json
import os

NOTE = {
    "compute": "more chips / higher MXU occupancy moves this",
    "memory": "fusion + bf16 activations cut HBM traffic",
    "collective": "resharding or larger per-device batch cuts ICI bytes",
}

# Nominal (peak_flops/s, peak_bytes/s) per platform for the kernel-tune
# %-of-roofline column. TPU = v5e MXU bf16 peak + HBM BW; CPU = a
# round-number container-class estimate (2 cores x AVX2 FMA, DDR) —
# documented approximations: the column is for comparing shapes and
# trajectories, not certifying hardware.
PEAKS = {
    "tpu": (1.97e14, 8.19e11),
    "cpu": (1.0e11, 2.0e10),
    "gpu": (1.0e13, 1.0e12),
}


def ff_dense_roofline(M, K, N, *, platform="cpu", dtype_bytes=4):
    """Analytic roofline for ONE fused ff_dense fwd + fused-bwd step
    (what the autotuner times): flops/bytes totals, the compute and
    memory terms, and the max-of-terms min time in seconds."""
    # fwd: matmul 2MKN + bias/relu/square-accumulate ~3MN
    # bwd: dy rebuild ~4MN + three products (dx, dw via 2MKN each)
    flops = 3 * (2 * M * K * N) + 7 * M * N
    # fused-path HBM traffic: x, w, b in; y, g out (fwd) + y, cots in;
    # dx, dw, db out (bwd) — activations never round-trip inside a step
    bytes_ = dtype_bytes * (3 * (M * K + K * N) + 3 * M * N
                            + 2 * N + 3 * M)
    peak_f, peak_b = PEAKS.get(platform, PEAKS["cpu"])
    t_compute = flops / peak_f
    t_memory = bytes_ / peak_b
    return {
        "flops": flops, "bytes": bytes_,
        "compute_term_s": t_compute, "memory_term_s": t_memory,
        "roof_s": max(t_compute, t_memory),
        "bound": "compute" if t_compute >= t_memory else "memory",
    }


def pct_of_roofline(measured_s, roof_s):
    """Measured time as % of the analytic ceiling (100 = at the roof;
    interpret-mode numbers land far below 1 by design)."""
    if not measured_s or measured_s <= 0:
        return 0.0
    return 100.0 * roof_s / measured_s


def load_records(dirpath="experiments/dryrun"):
    recs = []
    if not os.path.isdir(dirpath):
        return recs
    for fn in sorted(os.listdir(dirpath)):
        if fn.endswith(".json"):
            with open(os.path.join(dirpath, fn)) as f:
                recs.append(json.load(f))
    return recs


def fmt_row(r):
    terms = {"compute": r["compute_term_s"], "memory": r["memory_term_s"],
             "collective": r["collective_term_s"]}
    dom = max(terms, key=terms.get)
    util = r.get("flops_utilization", 0.0)
    return (f"| {r['arch']:24s} | {r['shape']:11s} "
            f"| {'2x16x16' if r['multi_pod'] else '16x16':7s} "
            f"| {terms['compute']:9.4f} | {terms['memory']:9.4f} "
            f"| {terms['collective']:10.4f} | {dom:10s} | {util:5.2f} |")


def print_table(recs, multi_pod=None):
    print("| arch | shape | mesh | compute_s | memory_s | "
          "collective_s | bottleneck | MF/HF |")
    print("|---|---|---|---|---|---|---|---|")
    for r in recs:
        if multi_pod is not None and r["multi_pod"] != multi_pod:
            continue
        print(fmt_row(r))


def main():
    recs = load_records()
    if not recs:
        # not silently empty: say exactly how to produce the records
        print("no dry-run records under experiments/dryrun — generate "
              "them first with:\n"
              "  PYTHONPATH=src python -m repro.launch.dryrun\n"
              "then re-run this section for the per-arch roofline "
              "table.")
        return
    n1 = sum(1 for r in recs if not r["multi_pod"])
    n2 = sum(1 for r in recs if r["multi_pod"])
    print(f"# Roofline ({n1} single-pod + {n2} multi-pod records)\n")
    print("## Single-pod (16x16 = 256 chips)")
    print_table(recs, multi_pod=False)
    if n2:
        print("\n## Multi-pod (2x16x16 = 512 chips)")
        print_table(recs, multi_pod=True)
    # bottleneck census
    census = {}
    for r in recs:
        if r["multi_pod"]:
            continue
        census[r["bottleneck"]] = census.get(r["bottleneck"], 0) + 1
    print("\nbottleneck census (single-pod):", census)


if __name__ == "__main__":
    main()
