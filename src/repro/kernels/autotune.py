"""Measure-many, pick-fastest kernel autotuner with a persisted tuning
table — nebullvm's compiler-framework idea applied to the FF hot loop.

For each ``(M, K, N, dtype, platform, norm)`` shape bucket the tuner
benchmarks every registered ``ff_dense`` impl — the tunable ones
(Pallas) across a grid of candidate block shapes ``(bm, bn)``, the rest
(the jnp oracle) as a single candidate — through ONE forward + fused
backward step (a jitted ``value_and_grad``, so the custom_vjp backward
kernel is part of what is timed), and:

  * rejects any candidate whose scale-normalized VALUE or GRAD error vs
    the ``ref`` oracle exceeds ``ERR_GATE`` (the same 1e-4 budget
    ``benchmarks/run.py`` enforces) — a fast-but-wrong impl never wins;
  * filters candidate block shapes through the VMEM row-residency
    invariant documented in ``ff_dense.py`` (``vmem_block_bytes`` <=
    ``VMEM_BUDGET_BYTES``): norm=True keeps the whole (bm, N) y row
    block resident across the inner j sweep (j-constant index map), so
    a shape that cannot fit is never even measured;
  * persists the winner in a JSON tuning table keyed like a compile
    cache (stable sorted keys, atomic replace), with in-memory
    memoization and an env-var path override ``REPRO_TUNE_TABLE``.

``ops.ff_dense(impl="auto")`` consults the table at TRACE time (shapes
are static under jit, so the lookup costs nothing at runtime): a hit
resolves to the measured-fastest impl with its tuned block shapes, a
miss falls back to the registry's platform default. Entries record both
the overall winner impl AND the best Pallas block shapes, so a caller
forcing ``impl="pallas"`` on a platform where the oracle won still gets
tuned blocks. A poisoned table (corrupt JSON, non-int blocks, shapes
breaking the residency budget, unregistered impl) degrades gracefully:
warn once and fall back to defaults, never crash.

Bit-exactness note (also recorded in the table meta): winners are gated
on the 1e-4 oracle error, NOT bit-exactness — a tuned block shape may
legitimately change float summation order on the Pallas path. The
pff-exec sequential-vs-executor weight-stream matrix therefore pins
``kernel_impl="ref"`` (see ``core.pff_exec._case_setup``) and stays
bit-exact with tuning on or off; this table only steers ``"auto"``.

The candidate axes are ``(bm, bn)`` today; ``bk`` joins the sweep once
the forward kernel tiles its inner K sweep (it currently streams K
whole — ``bk`` only parameterizes the fused backward, where it rides
along at its default).

Timing is injectable (``timer=``) so tests can pin a seeded fake timer
and assert a deterministic winner; the default wall-clock timer takes
the best of ``repeats`` blocked calls after a compile warmup.
"""
from __future__ import annotations

import json
import os
import time
import warnings

import jax
import jax.numpy as jnp

from repro.kernels import registry as registry_lib
from repro.kernels.ff_dense import VMEM_BUDGET_BYTES, vmem_block_bytes
from repro.obs import trace as obs_trace

# Same correctness budget as benchmarks.run.ERR_BUDGET (not imported:
# src/ must not depend on the benchmarks package).
ERR_GATE = 1e-4

DEFAULT_TABLE_PATH = os.path.join(
    os.path.expanduser("~"), ".cache", "repro", "tune_table.json")

TABLE_META = {
    "format": "repro-kernel-tune-v1",
    "err_gate": ERR_GATE,
    "note": (
        "Winners are gated on scale-normalized value AND fused-grad "
        "error vs the ref oracle (<= err_gate), not on bit-exactness: "
        "a tuned Pallas block shape may legitimately change float "
        "summation order. The pff-exec sequential-vs-executor "
        "bit-exactness matrix pins kernel_impl='ref' and is therefore "
        "immune to this table; only impl='auto' (and the block shapes "
        "of a forced impl='pallas') read it."),
}

# candidate axes; the generator clamps/filters per shape
_BM_CANDIDATES = (8, 16, 32, 64, 128, 256)
_BN_CANDIDATES = (128, 256, 512)


def table_path():
    """Resolved table location: ``REPRO_TUNE_TABLE`` env override, else
    the per-user cache default."""
    return os.environ.get("REPRO_TUNE_TABLE") or DEFAULT_TABLE_PATH


def key_for(op, M, K, N, dtype, platform, norm):
    """Compile-cache-style table key for one shape bucket."""
    dtype = jnp.dtype(dtype).name
    return (f"{op}|M={M}|K={K}|N={N}|dtype={dtype}"
            f"|platform={platform}|norm={int(bool(norm))}")


def candidate_blocks(M, K, N, *, norm=False, budget=VMEM_BUDGET_BYTES):
    """The legal (bm, bn) grid for one shape: clamped to the operand
    (the kernel would clamp anyway — clamping here dedupes), lane-
    aligned (bn a 128-multiple unless it IS N), and within the VMEM
    row-residency budget (see ``ff_dense.vmem_block_bytes``)."""
    bms = sorted({min(bm, M) for bm in _BM_CANDIDATES})
    bns = sorted({min(bn, N) for bn in _BN_CANDIDATES})
    out = []
    for bm in bms:
        for bn in bns:
            if bn % 128 and bn != N:
                continue                      # misaligned lane dim
            if vmem_block_bytes(K, N, bm, bn, norm=norm) > budget:
                continue                      # breaks row residency
            out.append((bm, bn))
    return out


# ---------------------------------------------------------------------------
# Tuning table: JSON persistence + in-memory memoization
# ---------------------------------------------------------------------------

class TuneTable:
    """The persisted winners, keyed by ``key_for``.

    Entry schema: {"impl": str, "time_s": float, "err": float,
    "grad_err": float} plus — whenever any Pallas candidate passed the
    gates — {"bm": int, "bn": int, "pallas_time_s": float} for the
    fastest passing Pallas block shape (``bk`` reserved for the future
    inner-sweep tiling).
    """

    def __init__(self, path=None):
        self.path = path or table_path()
        self.meta = dict(TABLE_META)
        self.entries = {}

    @classmethod
    def open(cls, path=None):
        return cls(path).load()

    def load(self):
        if not os.path.exists(self.path):
            return self
        try:
            with open(self.path) as f:
                raw = json.load(f)
            entries = raw["entries"]
            if not isinstance(entries, dict):
                raise ValueError("'entries' is not an object")
        except (OSError, json.JSONDecodeError, KeyError, ValueError,
                TypeError) as e:
            warnings.warn(
                f"poisoned kernel tuning table at {self.path} ({e}); "
                f"ignoring it and falling back to default block shapes")
            return self
        self.entries = entries
        self.meta = raw.get("meta", self.meta)
        return self

    def save(self):
        """Atomic write with byte-stable key ordering (sort_keys), so a
        re-tune that changes nothing leaves the file bit-identical."""
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"meta": self.meta, "entries": self.entries}, f,
                      indent=2, sort_keys=True)
            f.write("\n")
        os.replace(tmp, self.path)
        invalidate_cache(self.path)
        return self.path

    def get(self, key):
        return self.entries.get(key)

    def put(self, key, entry):
        self.entries[key] = entry

    def __len__(self):
        return len(self.entries)


# path -> TuneTable; ops.ff_dense consults this at trace time, so one
# process reads the file at most once per path (STATS proves the memo
# in `make tune-smoke` and the tests).
_CACHE = {}
STATS = {"loads": 0, "memo_hits": 0}


def cached_table():
    path = table_path()
    if path in _CACHE:
        STATS["memo_hits"] += 1
        return _CACHE[path]
    STATS["loads"] += 1
    t = TuneTable.open(path)
    _CACHE[path] = t
    return t


def invalidate_cache(path=None):
    """Drop the in-memory table memo (one path, or all)."""
    if path is None:
        _CACHE.clear()
    else:
        _CACHE.pop(path, None)


def _validated(entry, key, op, K, N, norm):
    """None (with a warning) unless the entry is shaped like a winner
    and its blocks honor the residency budget — the poisoned-table
    fallback path."""
    try:
        impl = entry["impl"]
        if not isinstance(impl, str):
            raise ValueError("impl is not a string")
        if impl not in registry_lib.registry(op):
            raise ValueError(f"impl {impl!r} is not registered")
        if "bm" in entry or "bn" in entry:
            bm, bn = entry["bm"], entry["bn"]
            if not (isinstance(bm, int) and bm > 0
                    and isinstance(bn, int) and bn > 0):
                raise ValueError(f"bad block shape ({bm!r}, {bn!r})")
            if vmem_block_bytes(K, N, bm, bn, norm=norm) \
                    > VMEM_BUDGET_BYTES:
                raise ValueError(
                    f"blocks ({bm}, {bn}) break the VMEM row-residency "
                    f"budget for K={K} N={N} norm={norm}")
        elif impl == "pallas":
            raise ValueError("pallas winner without block shapes")
    except (KeyError, ValueError, TypeError) as e:
        warnings.warn(f"poisoned tuning-table entry {key!r} ({e}); "
                      f"falling back to default block shapes")
        return None
    return entry


def lookup(op, M, K, N, dtype, platform, *, norm=False):
    """Trace-time table consultation for ``ops``: the validated winning
    entry for this shape bucket, or None (use registry defaults)."""
    t = cached_table()
    key = key_for(op, M, K, N, dtype, platform, norm)
    entry = t.get(key)
    if entry is None:
        return None
    return _validated(entry, key, op, K, N, norm)


def entry_blocks(entry):
    """An entry's tuned ``(bm, bn, bk)`` tuple, or None if it has no
    Pallas block shapes (e.g. only the oracle passed the gates)."""
    if "bm" not in entry:
        return None
    return (entry["bm"], entry["bn"], entry.get("bk"))


# ---------------------------------------------------------------------------
# The sweep
# ---------------------------------------------------------------------------

def _tune_data(key, M, K, N, dtype):
    kx, kw, ky, kg = jax.random.split(key, 4)
    x = jax.random.normal(kx, (M, K), dtype)
    w = (jax.random.normal(kw, (K, N)) * K ** -0.5).astype(dtype)
    b = jnp.full((N,), 0.1, dtype)
    # cotangents exercising BOTH outputs (y through cy, raw goodness
    # through cg) so the fused backward's dg path is gated too
    cy = jax.random.normal(ky, (M, N), jnp.float32) * 0.01
    cg = jax.random.normal(kg, (M,), jnp.float32) * 0.01
    return x, w, b, cy, cg


def _make_loss(fn, norm, interpret, blocks):
    def loss(w, x, b, cy, cg):
        y, g = fn(x, w, b, norm=norm, interpret=interpret, blocks=blocks)
        return jnp.vdot(y.astype(jnp.float32), cy) + jnp.vdot(g, cg)
    return loss


def _scale_err(a, b):
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    return float(jnp.abs(a - b).max() / (jnp.abs(b).max() + 1e-9))


def _candidate_errors(impl_name, blocks, data, oracle, *, norm,
                      interpret):
    """(value_err, grad_err) of one candidate vs the ref oracle —
    scale-normalized, same convention as ``benchmarks/kernels.py``."""
    fn = registry_lib.ff_dense.get(impl_name).fn
    x, w, b, cy, cg = data
    y, g = fn(x, w, b, norm=norm, interpret=interpret, blocks=blocks)
    dw = jax.grad(_make_loss(fn, norm, interpret, blocks))(w, x, b, cy,
                                                           cg)
    y_r, g_r, dw_r = oracle
    err = max(_scale_err(y, y_r), _scale_err(g, g_r))
    grad_err = _scale_err(dw, dw_r)
    return err, grad_err


def _wall_timer(thunk, label, repeats=2):
    """Best-of-``repeats`` wall clock after one compile/warmup call."""
    del label
    jax.block_until_ready(thunk())
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(thunk())
        best = min(best, time.perf_counter() - t0)
    return best


def tune_ff_dense(shapes, *, norms=(False, True), dtype=jnp.float32,
                  table=None, timer=None, err_gate=ERR_GATE, seed=0,
                  max_candidates=None, save=True, verbose=True,
                  tracer=obs_trace.NOOP):
    """Sweep ``shapes`` (iterable of (M, K, N)), persist winners.

    Returns a list of per-bucket row dicts (winner, best blocks, ref
    baseline, rejected candidates) — what ``benchmarks/kernels.py``
    turns into BENCH_kernel_tune.json. ``timer(thunk, label) -> s`` is
    injectable; ``max_candidates`` bounds the Pallas grid per bucket
    (smoke mode). ``save=True`` writes the table and drops the memo so
    subsequent ``lookup``s see the new winners. ``tracer=`` (an
    ``obs.trace`` tracer) records one ``tune:candidate`` span per
    measured candidate and a ``tune:reject`` event per gate breach.
    """
    platform = jax.default_backend()
    interpret = platform != "tpu"
    if table is None:
        table = TuneTable.open()
    if timer is None:
        timer = _wall_timer
    rows = []
    root = jax.random.PRNGKey(seed)
    for si, (M, K, N) in enumerate(shapes):
        for norm in norms:
            key = key_for("ff_dense", M, K, N, dtype, platform, norm)
            data = _tune_data(
                jax.random.fold_in(root, 2 * si + int(norm)),
                M, K, N, dtype)
            x, w, b, cy, cg = data
            ref_fn = registry_lib.ff_dense.get("ref").fn
            y_r, g_r = ref_fn(x, w, b, norm=norm, interpret=interpret,
                              blocks=None)
            dw_r = jax.grad(_make_loss(ref_fn, norm, interpret, None))(
                w, x, b, cy, cg)
            oracle = (y_r, g_r, dw_r)

            cands = []
            for name in registry_lib.ff_dense.names():
                if name in registry_lib.ff_dense.tunable_names():
                    grid = candidate_blocks(M, K, N, norm=norm)
                    if max_candidates and len(grid) > max_candidates:
                        # smoke mode: keep an evenly-spaced spread that
                        # always includes the largest blocks (fewest
                        # grid steps — the usual winners), so the
                        # truncated sweep still explores the range
                        step = len(grid) / max_candidates
                        grid = [grid[len(grid) - 1 - int(i * step)]
                                for i in range(max_candidates)][::-1]
                    cands += [(name, (bm, bn, None)) for bm, bn in grid]
                else:
                    cands.append((name, None))

            measured, rejected = [], []
            for name, blocks in cands:
                label = f"{key}|{name}" + (
                    f"|bm={blocks[0]}|bn={blocks[1]}" if blocks else "")
                try:
                    err, grad_err = _candidate_errors(
                        name, blocks, data, oracle, norm=norm,
                        interpret=interpret)
                except Exception as e:  # an impl that cannot even run
                    rejected.append({"impl": name, "blocks": blocks,
                                     "reason": f"raised {e!r}"})
                    if tracer.enabled:
                        tracer.event("tune:reject", key=key, impl=name,
                                     reason="raised")
                    continue
                if err > err_gate or grad_err > err_gate:
                    rejected.append({
                        "impl": name, "blocks": blocks,
                        "reason": (f"oracle error breach: err={err:.2e} "
                                   f"grad_err={grad_err:.2e} > "
                                   f"{err_gate:.0e}")})
                    if tracer.enabled:
                        tracer.event("tune:reject", key=key, impl=name,
                                     reason="oracle_error", err=err,
                                     grad_err=grad_err)
                    continue
                step = jax.jit(jax.value_and_grad(
                    _make_loss(registry_lib.ff_dense.get(name).fn,
                               norm, interpret, blocks)))
                t0_m = tracer.now()
                t = timer(lambda: step(w, x, b, cy, cg), label)
                if tracer.enabled:
                    tracer.add_span(
                        "tune:candidate", t0_m, key=key, impl=name,
                        bm=blocks[0] if blocks else None,
                        bn=blocks[1] if blocks else None,
                        time_s=float(t))
                measured.append({"impl": name, "blocks": blocks,
                                 "time_s": float(t), "err": err,
                                 "grad_err": grad_err})
            if not measured:
                warnings.warn(f"no candidate passed the {err_gate:.0e} "
                              f"oracle gate for {key}; bucket left "
                              f"untuned")
                rows.append({"key": key, "M": M, "K": K, "N": N,
                             "norm": norm, "winner": None,
                             "rejected": rejected})
                continue

            best = min(measured, key=lambda m: m["time_s"])
            entry = {"impl": best["impl"], "time_s": best["time_s"],
                     "err": best["err"], "grad_err": best["grad_err"]}
            pallas = [m for m in measured if m["blocks"] is not None]
            if pallas:
                bp = min(pallas, key=lambda m: m["time_s"])
                entry["bm"], entry["bn"] = bp["blocks"][0], bp["blocks"][1]
                entry["pallas_time_s"] = bp["time_s"]
            ref_m = [m for m in measured if m["impl"] == "ref"]
            if ref_m:
                entry["ref_time_s"] = ref_m[0]["time_s"]
            table.put(key, entry)
            rows.append({"key": key, "M": M, "K": K, "N": N,
                         "norm": norm, "winner": dict(entry),
                         "n_candidates": len(cands),
                         "n_rejected": len(rejected),
                         "rejected": rejected})
            if verbose:
                blk = (f" bm={entry['bm']} bn={entry['bn']}"
                       if "bm" in entry else "")
                print(f"  {key}: winner={entry['impl']}{blk} "
                      f"t={entry['time_s']:.4g}s "
                      f"err={entry['err']:.1e} "
                      f"grad_err={entry['grad_err']:.1e} "
                      f"({len(measured)} passed, {len(rejected)} "
                      f"rejected)")
    if save:
        path = table.save()
        if verbose:
            print(f"  tuning table: {len(table)} entries -> {path}")
    return rows
