"""The paper's chapter schedule applied to transformer stacks.

``core.train.make_ff_train_step`` trains every block each step ("joint
FF" — all local losses in one fused pass, the TPU-native formulation).
This module implements the paper's ACTUAL schedule instead: chapters of
per-BLOCK training (chapter c trains block k for a fixed step budget on
the outputs of blocks < k), producing the same TaskRecord stream the
PFF simulator consumes — so the paper's Single-Layer / All-Layers
wall-clock analysis applies to the assigned architectures directly.

This is the bridge between the paper's MLP experiments and the
production archs: FF locality means the chapter schedule and the joint
step optimize the same per-block objectives; the schedule only changes
WHEN each block's updates happen (and therefore what its inputs look
like). The benchmark compares both on eval CE.
"""
from __future__ import annotations

import functools
import time
from typing import List

import jax
import jax.numpy as jnp

from repro import optim
from repro.core import ff
from repro.core import train as train_lib
from repro.core.pff import TaskRecord
from repro.models import blocks, common, transformer
from repro.models.mlp import NO_DIST


def _slice_unit(tree, k):
    return jax.tree.map(lambda a: a[k], tree)


def _set_unit(tree, unit, k):
    return jax.tree.map(lambda a, u: a.at[k].set(u), tree, unit)


def make_block_step(cfg, *, lr=1e-3, seed=0, theta=None):
    """Returns step(params, opt, batch, block_idx, step_no) that updates
    ONLY block ``block_idx`` (plus nothing else — the paper's per-node
    task). Single-group architectures (uniform stacks)."""
    assert len(cfg.groups) == 1, "chapter schedule needs a uniform stack"
    pattern, repeat = cfg.groups[0]
    theta = theta if theta is not None else cfg.ff.theta

    @functools.partial(jax.jit, static_argnames=("block_idx",))
    def step(params, opt_state, batch, block_idx, step_no):
        assert 0 <= block_idx < repeat, (block_idx, repeat)
        tokens = batch["tokens"][:, :-1]
        B = tokens.shape[0]
        key = jax.random.fold_in(jax.random.PRNGKey(seed), step_no)
        neg = ff.corrupt_tokens(key, tokens, cfg.vocab)
        x = jnp.take(params["embed"],
                     jnp.concatenate([tokens, neg], axis=0), axis=0)
        is_pos = jnp.concatenate(
            [jnp.ones((B,)), jnp.zeros((B,))]).astype(jnp.float32)
        ctx = {"causal": True, "dist": NO_DIST}

        gp = params["groups"][0]

        # frozen forward through blocks < block_idx
        def fwd_body(carry, unit_p):
            h = carry
            for kind, bp in zip(pattern, unit_p):
                h, _ = blocks.block_apply(bp, cfg, kind, h, ctx)
            return h, None

        if block_idx > 0:
            prefix = jax.tree.map(lambda a: a[:block_idx], gp)
            x, _ = jax.lax.scan(fwd_body, x, prefix)
        x = jax.lax.stop_gradient(x)

        unit_p = _slice_unit(gp, block_idx)
        unit_m = _slice_unit(opt_state["m"]["groups"][0], block_idx)
        unit_v = _slice_unit(opt_state["v"]["groups"][0], block_idx)

        def loss_fn(up):
            h = x
            total = jnp.zeros(())
            for kind, bp in zip(pattern, up):
                h_sg = jax.lax.stop_gradient(h)
                y, moe_aux = blocks.block_apply(bp, cfg, kind, h_sg, ctx)
                g = ff.mean_goodness(y - h_sg)
                total = total + ff.ff_loss_masked(g, is_pos, theta) \
                    + 0.01 * moe_aux
                h = y
            return total

        loss, grads = jax.value_and_grad(loss_fn)(unit_p)
        new_unit, st = optim.adam_update(
            unit_p, grads, {"m": unit_m, "v": unit_v}, lr=lr,
            step=step_no)
        new_params = dict(params)
        new_params["groups"] = (_set_unit(gp, new_unit, block_idx),)
        new_m = dict(opt_state["m"])
        new_v = dict(opt_state["v"])
        new_m["groups"] = (_set_unit(opt_state["m"]["groups"][0],
                                     st["m"], block_idx),)
        new_v["groups"] = (_set_unit(opt_state["v"]["groups"][0],
                                     st["v"], block_idx),)
        return new_params, {"m": new_m, "v": new_v}, loss

    return step


def head_param_names(cfg):
    """The per-chapter head task's parameter subset: ``final_norm``
    plus the softmax weights — the tied embedding table (which then
    doubles as the paper's softmax layer, exactly like the joint step
    in ``core/train.py``) or the untied ``lm_head``."""
    return ("final_norm", "embed" if cfg.tie_embeddings else "lm_head")


def make_head_step(cfg, *, head_lr=1e-3):
    """Returns head_step(params, opt, batch, step_no) — the per-chapter
    softmax-head task (DAG ``Task("head", n_layers, c)``): a frozen
    forward through ALL blocks, then local CE on the head subset only
    (``head_param_names``). Mirrors the joint step's head treatment:
    features are stop-gradded, so a tied table receives the CE grad
    only through the unembed."""
    assert len(cfg.groups) == 1, "chapter schedule needs a uniform stack"
    pattern, _ = cfg.groups[0]
    names = head_param_names(cfg)

    @jax.jit
    def head_step(params, opt_state, batch, step_no):
        tokens = batch["tokens"]
        inp, labels = tokens[:, :-1], tokens[:, 1:]
        x = jnp.take(params["embed"], inp, axis=0)
        ctx = {"causal": True, "dist": NO_DIST}

        def fwd_body(carry, unit_p):
            h = carry
            for kind, bp in zip(pattern, unit_p):
                h, _ = blocks.block_apply(bp, cfg, kind, h, ctx)
            return h, None

        x, _ = jax.lax.scan(fwd_body, x, params["groups"][0])
        x = jax.lax.stop_gradient(x)

        def head_loss(hp):
            h = common.rms_norm(x, hp["final_norm"], cfg.norm_eps)
            w = hp["embed"] if cfg.tie_embeddings else hp["lm_head"].T
            ones = jnp.ones(labels.shape, jnp.float32)
            total = train_lib._ce_chunked(h, w, labels, ones,
                                          softcap=cfg.logit_softcap)
            return total / labels.size

        hp = {k: params[k] for k in names}
        loss, grads = jax.value_and_grad(head_loss)(hp)
        new_hp, st = optim.adam_update(
            hp, grads,
            {"m": {k: opt_state["m"][k] for k in names},
             "v": {k: opt_state["v"][k] for k in names}},
            lr=head_lr, step=step_no)
        new_params = dict(params)
        new_m = dict(opt_state["m"])
        new_v = dict(opt_state["v"])
        for k in names:
            new_params[k] = new_hp[k]
            new_m[k] = st["m"][k]
            new_v[k] = st["v"][k]
        return new_params, {"m": new_m, "v": new_v}, loss

    return head_step


def chapter_batches(source, *, batch, steps):
    """The canonical (chapter, task)-addressed batch stream over a
    ``data.TextSource``-style source: a pure function of its arguments
    (the ``data.Source`` contract), so the sequential trainer and EVERY
    executor node regenerate identical batches locally — training data
    never crosses the hand-off. The head task is addressed as
    ``block = n_blocks`` (its DAG layer index)."""
    def data_iter(chapter, block):
        blk = source.blocks("train", batch * steps,
                            seed=chapter * 1009 + block)
        for s in range(steps):
            yield {"tokens": jnp.asarray(blk[s * batch:(s + 1) * batch])}
    return data_iter


def train_chapters(cfg, data_iter_fn, *, chapters, steps_per_chapter,
                   lr=1e-3, head_lr=None, seed=0):
    """Runs the chapter schedule; returns (params, records, ff_losses).

    data_iter_fn(chapter, block) -> iterable of batches for that task;
    the per-chapter head task draws ``data_iter_fn(c, n_blocks)``.
    The LM head (final_norm + lm_head/embed-as-softmax) trains at the
    end of each chapter, like the paper's softmax layer, at ``head_lr``
    (default: ``lr``); its ``TaskRecord("head", n_blocks, c)`` rides
    the same record stream the simulator consumes. ``ff_losses`` stays
    train-task-only (one FF loss per block task, the historical
    contract) — head CE is observable through ``train.eval_ce``.
    """
    key = jax.random.PRNGKey(seed)
    params = transformer.init(key, cfg)
    opt = optim.adam_init(params)
    step = make_block_step(cfg, lr=lr, seed=seed)
    head_step = make_head_step(
        cfg, head_lr=lr if head_lr is None else head_lr)
    _, repeat = cfg.groups[0][0], cfg.groups[0][1]
    records: List[TaskRecord] = []
    losses = []
    n = 0
    n_head = 0
    for c in range(chapters):
        for k in range(repeat):
            t0 = time.perf_counter()
            last = None
            for batch in data_iter_fn(c, k):
                n += 1
                params, opt, last = step(params, opt, batch, k, n)
            jax.block_until_ready(last)
            records.append(TaskRecord("train", k, c,
                                      time.perf_counter() - t0))
            losses.append(float(last))
        t0 = time.perf_counter()
        last = None
        for batch in data_iter_fn(c, repeat):
            n_head += 1
            params, opt, last = head_step(params, opt, batch, n_head)
        jax.block_until_ready(last)
        records.append(TaskRecord("head", repeat, c,
                                  time.perf_counter() - t0))
    return params, records, losses


def lm_params_bit_equal(a, b) -> bool:
    """True iff two transformer params pytrees are BIT-identical on
    every leaf — the LM executor's correctness oracle (the transformer
    analog of ``pff_exec.params_bit_equal``)."""
    fa, fb = jax.tree.leaves(a), jax.tree.leaves(b)
    return (jax.tree.structure(a) == jax.tree.structure(b)
            and all(bool(jnp.array_equal(x, y))
                    for x, y in zip(fa, fb)))
