"""Joint-FF vs the paper's chapter schedule on a reduced transformer.

The joint step (core/train.py) updates every block each batch; the
chapter schedule (core/pff_lm.py) trains one block at a time on the
frozen outputs of the blocks below — the paper's task granularity,
which is what pipelines across nodes. Both optimize the same per-block
local objectives; this benchmark compares eval CE at an equal update
budget and reports the PFF schedule times for the chapter variant.
"""
from __future__ import annotations

import dataclasses
import json
import os

import jax
import jax.numpy as jnp

from repro import data as data_lib, optim
from repro.configs import get_config
from repro.core import pff, pff_lm, train as train_lib
from repro.models import transformer

NODES = 4


def run(arch="qwen2-0.5b", blocks=4, chapters=4, steps_per_chapter=8,
        batch=8, seq=64, lr=3e-3, out_dir="experiments"):
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(cfg, num_layers=blocks,
                              groups=((("attn",), blocks),))
    key = jax.random.PRNGKey(0)
    eval_tokens = jnp.asarray(next(iter(
        data_lib.lm_batches(cfg.vocab, 16, seq, 1, seed=321))))
    total_updates = chapters * blocks * steps_per_chapter

    # ---- joint FF (every block each step) -------------------------------
    params = transformer.init(key, cfg)
    opt = optim.adam_init(params)
    step_fn = jax.jit(train_lib.make_ff_train_step(cfg, lr=lr))
    joint_steps = total_updates // blocks     # same per-block update count
    for i, tokens in enumerate(data_lib.lm_batches(
            cfg.vocab, batch, seq, joint_steps, seed=0)):
        params, opt, _ = step_fn(params, opt,
                                 {"tokens": jnp.asarray(tokens)}, i + 1)
    ce_joint = float(train_lib.eval_ce(params, cfg, eval_tokens))

    # ---- chapter schedule ------------------------------------------------
    def data_iter(chapter, block):
        return ({"tokens": jnp.asarray(t)} for t in data_lib.lm_batches(
            cfg.vocab, batch, seq, steps_per_chapter,
            seed=chapter * 1009 + block))

    params_c, records, _ = pff_lm.train_chapters(
        cfg, data_iter, chapters=chapters,
        steps_per_chapter=steps_per_chapter, lr=lr)
    ce_chap = float(train_lib.eval_ce(params_c, cfg, eval_tokens))

    sims = {}
    for sched in ("sequential", "single_layer", "all_layers"):
        s = pff.simulate_schedule(records, sched,
                                  1 if sched == "sequential" else NODES)
        sims[sched] = {"time_s": round(s.makespan, 2),
                       "speedup": round(s.speedup, 2)}

    res = {"arch": arch, "blocks": blocks,
           "per_block_updates": chapters * steps_per_chapter,
           "ce_joint": round(ce_joint, 3),
           "ce_chapters": round(ce_chap, 3),
           "schedules": sims}
    print(f"  joint-FF eval CE {ce_joint:.3f} | chapter-FF eval CE "
          f"{ce_chap:.3f} (equal per-block updates)")
    print(f"  chapter-FF PFF times: " + "  ".join(
        f"{k}={v['time_s']}s (x{v['speedup']})" for k, v in sims.items()))
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "lm_schedules.json"), "w") as f:
        json.dump(res, f, indent=1)
    return res


if __name__ == "__main__":
    run()
