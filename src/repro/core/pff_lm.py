"""The paper's chapter schedule applied to transformer stacks.

``core.train.make_ff_train_step`` trains every block each step ("joint
FF" — all local losses in one fused pass, the TPU-native formulation).
This module implements the paper's ACTUAL schedule instead: chapters of
per-BLOCK training (chapter c trains block k for a fixed step budget on
the outputs of blocks < k), producing the same TaskRecord stream the
PFF simulator consumes — so the paper's Single-Layer / All-Layers
wall-clock analysis applies to the assigned architectures directly.

This is the bridge between the paper's MLP experiments and the
production archs: FF locality means the chapter schedule and the joint
step optimize the same per-block objectives; the schedule only changes
WHEN each block's updates happen (and therefore what its inputs look
like). The benchmark compares both on eval CE.
"""
from __future__ import annotations

import functools
import time
from typing import List

import jax
import jax.numpy as jnp

from repro import optim
from repro.core import ff
from repro.core.pff import TaskRecord
from repro.models import blocks, transformer
from repro.models.mlp import NO_DIST


def _slice_unit(tree, k):
    return jax.tree.map(lambda a: a[k], tree)


def _set_unit(tree, unit, k):
    return jax.tree.map(lambda a, u: a.at[k].set(u), tree, unit)


def make_block_step(cfg, *, lr=1e-3, seed=0, theta=None):
    """Returns step(params, opt, batch, block_idx, step_no) that updates
    ONLY block ``block_idx`` (plus nothing else — the paper's per-node
    task). Single-group architectures (uniform stacks)."""
    assert len(cfg.groups) == 1, "chapter schedule needs a uniform stack"
    pattern, repeat = cfg.groups[0]
    theta = theta if theta is not None else cfg.ff.theta

    @functools.partial(jax.jit, static_argnames=("block_idx",))
    def step(params, opt_state, batch, block_idx, step_no):
        assert 0 <= block_idx < repeat, (block_idx, repeat)
        tokens = batch["tokens"][:, :-1]
        B = tokens.shape[0]
        key = jax.random.fold_in(jax.random.PRNGKey(seed), step_no)
        neg = ff.corrupt_tokens(key, tokens, cfg.vocab)
        x = jnp.take(params["embed"],
                     jnp.concatenate([tokens, neg], axis=0), axis=0)
        is_pos = jnp.concatenate(
            [jnp.ones((B,)), jnp.zeros((B,))]).astype(jnp.float32)
        ctx = {"causal": True, "dist": NO_DIST}

        gp = params["groups"][0]

        # frozen forward through blocks < block_idx
        def fwd_body(carry, unit_p):
            h = carry
            for kind, bp in zip(pattern, unit_p):
                h, _ = blocks.block_apply(bp, cfg, kind, h, ctx)
            return h, None

        if block_idx > 0:
            prefix = jax.tree.map(lambda a: a[:block_idx], gp)
            x, _ = jax.lax.scan(fwd_body, x, prefix)
        x = jax.lax.stop_gradient(x)

        unit_p = _slice_unit(gp, block_idx)
        unit_m = _slice_unit(opt_state["m"]["groups"][0], block_idx)
        unit_v = _slice_unit(opt_state["v"]["groups"][0], block_idx)

        def loss_fn(up):
            h = x
            total = jnp.zeros(())
            for kind, bp in zip(pattern, up):
                h_sg = jax.lax.stop_gradient(h)
                y, moe_aux = blocks.block_apply(bp, cfg, kind, h_sg, ctx)
                g = ff.mean_goodness(y - h_sg)
                total = total + ff.ff_loss_masked(g, is_pos, theta) \
                    + 0.01 * moe_aux
                h = y
            return total

        loss, grads = jax.value_and_grad(loss_fn)(unit_p)
        new_unit, st = optim.adam_update(
            unit_p, grads, {"m": unit_m, "v": unit_v}, lr=lr,
            step=step_no)
        new_params = dict(params)
        new_params["groups"] = (_set_unit(gp, new_unit, block_idx),)
        new_m = dict(opt_state["m"])
        new_v = dict(opt_state["v"])
        new_m["groups"] = (_set_unit(opt_state["m"]["groups"][0],
                                     st["m"], block_idx),)
        new_v["groups"] = (_set_unit(opt_state["v"]["groups"][0],
                                     st["v"], block_idx),)
        return new_params, {"m": new_m, "v": new_v}, loss

    return step


def train_chapters(cfg, data_iter_fn, *, chapters, steps_per_chapter,
                   lr=1e-3, head_lr=None, seed=0):
    """Runs the chapter schedule; returns (params, records, ff_losses).

    data_iter_fn(chapter, block) -> iterable of batches for that task.
    The LM head (final_norm + lm_head/embed-as-softmax) trains at the
    end of each chapter, like the paper's softmax layer.
    """
    key = jax.random.PRNGKey(seed)
    params = transformer.init(key, cfg)
    opt = optim.adam_init(params)
    step = make_block_step(cfg, lr=lr, seed=seed)
    _, repeat = cfg.groups[0][0], cfg.groups[0][1]
    records: List[TaskRecord] = []
    losses = []
    n = 0
    for c in range(chapters):
        for k in range(repeat):
            t0 = time.perf_counter()
            last = None
            for batch in data_iter_fn(c, k):
                n += 1
                params, opt, last = step(params, opt, batch, k, n)
            jax.block_until_ready(last)
            records.append(TaskRecord("train", k, c,
                                      time.perf_counter() - t0))
            losses.append(float(last))
    return params, records, losses
