"""qwen2-0.5b — GQA with QKV bias [arXiv:2407.10671]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-0.5b",
    arch_type="dense",
    num_layers=24,
    d_model=896,
    n_heads=14,
    n_kv=2,
    d_ff=4864,
    vocab=151936,
    qkv_bias=True,
    rope_theta=1e6,
    tie_embeddings=True,
    groups=((("attn",), 24),),
    source="arXiv:2407.10671 (Qwen2)",
))
