"""Self-contained byte-level BPE tokenizer (GPT-2-style merges).

The vocabulary starts from the 256 possible bytes, so ANY string
encodes and ``decode(encode(s)) == s`` exactly — the identity property
the deterministic data pipeline is built on (``data.TextSource``).
Merges are learned greedily on the checked-in corpus sample
(``corpus_sample.txt``): each round merges the most frequent adjacent
pair into a new token, ties broken by lowest pair ids, so training is
a pure function of ``(text, vocab_size)`` — every node reconstructs
the identical tokenizer with zero communication, the same contract
``data.Source`` promises for batches.

Text is pre-split into word-ish chunks (letters / digits / punctuation
runs, each with an optional leading space, GPT-2-style) so merges never
cross a word boundary; the split is a partition of the input, which is
what guarantees the round-trip. No external deps, no downloaded merge
table — the container is offline.
"""
from __future__ import annotations

import functools
import os
import re
from collections import Counter
from typing import Dict, List, Tuple

# Partition (not just match) of any string: every char is whitespace,
# a letter, a digit, or other; a single leading space attaches to the
# following chunk (GPT-2's " word" convention) and `\s+(?!\S)` stops a
# whitespace run one short of a following chunk so that space is left
# for it.
_SPLIT = re.compile(
    r" ?[A-Za-z]+| ?[0-9]+| ?[^\sA-Za-z0-9]+|\s+(?!\S)|\s+")

_CORPUS_PATH = os.path.join(os.path.dirname(__file__),
                            "corpus_sample.txt")


def corpus_text() -> str:
    """The checked-in corpus sample (training text for the default
    encoder AND the default ``data.TextSource`` token stream)."""
    with open(_CORPUS_PATH, encoding="utf-8") as f:
        return f.read()


def _merge(ids: List[int], pair: Tuple[int, int], new_id: int
           ) -> List[int]:
    """One pass replacing every occurrence of ``pair`` with ``new_id``."""
    out = []
    i = 0
    while i < len(ids):
        if i + 1 < len(ids) and (ids[i], ids[i + 1]) == pair:
            out.append(new_id)
            i += 2
        else:
            out.append(ids[i])
            i += 1
    return out


class Encoder:
    """Byte-level BPE encoder/decoder over an ordered merge list.

    ``merges[i]`` is the pair merged into token ``256 + i``; rank order
    IS priority order at encode time (lowest rank merges first), exactly
    the greedy scheme the trainer used — so encoding the training text
    reproduces the trainer's final symbol stream.
    """

    def __init__(self, merges: List[Tuple[int, int]]):
        self.merges: Dict[Tuple[int, int], int] = {
            pair: 256 + i for i, pair in enumerate(merges)}
        self._bytes: List[bytes] = [bytes([i]) for i in range(256)]
        for a, b in merges:
            self._bytes.append(self._bytes[a] + self._bytes[b])
        self._cache: Dict[str, Tuple[int, ...]] = {}

    @property
    def n_vocab(self) -> int:
        return len(self._bytes)

    def _encode_chunk(self, chunk: str) -> Tuple[int, ...]:
        ids = list(chunk.encode("utf-8"))
        while len(ids) >= 2:
            # lowest-rank pair present merges next (ties impossible:
            # ranks are unique)
            pair = min(zip(ids, ids[1:]),
                       key=lambda p: self.merges.get(p, 1 << 30))
            if pair not in self.merges:
                break
            ids = _merge(ids, pair, self.merges[pair])
        return tuple(ids)

    def encode(self, text: str) -> List[int]:
        out: List[int] = []
        for chunk in _SPLIT.findall(text):
            ids = self._cache.get(chunk)
            if ids is None:
                ids = self._encode_chunk(chunk)
                self._cache[chunk] = ids
            out.extend(ids)
        return out

    def decode(self, ids) -> str:
        return b"".join(self._bytes[int(i)] for i in ids).decode(
            "utf-8", errors="replace")


def train_bpe(text: str, vocab_size: int) -> Encoder:
    """Greedy BPE on ``text`` up to ``vocab_size`` tokens (>= 256).

    Deterministic: pair counts are exact, the winner is
    ``max((count, -a, -b))`` so ties resolve to the lowest pair ids
    regardless of dict iteration order. Stops early if no pair repeats.
    """
    if vocab_size < 256:
        raise ValueError(f"byte-level BPE needs vocab_size >= 256, "
                         f"got {vocab_size}")
    words = Counter(_SPLIT.findall(text))
    seqs = {w: list(w.encode("utf-8")) for w in words}
    merges: List[Tuple[int, int]] = []
    for new_id in range(256, vocab_size):
        counts: Counter = Counter()
        for w, n in words.items():
            s = seqs[w]
            for pair in zip(s, s[1:]):
                counts[pair] += n
        if not counts:
            break
        best = max(counts, key=lambda p: (counts[p], -p[0], -p[1]))
        if counts[best] < 2:
            break
        merges.append(best)
        for w in seqs:
            if best[0] in seqs[w]:
                seqs[w] = _merge(seqs[w], best, new_id)
    return Encoder(merges)


@functools.lru_cache(maxsize=None)
def default_encoder(vocab_size: int = 512) -> Encoder:
    """The repo's default tokenizer: BPE trained on the checked-in
    corpus sample (memoized per vocab size)."""
    return train_bpe(corpus_text(), vocab_size)
