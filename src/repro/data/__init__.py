"""Synthetic data pipelines (container is offline — no real MNIST/CIFAR).

Image tasks: deterministic class-prototype generators. Each class has a
smooth random prototype; samples are ``clip(proto + noise)``. ``mnist_like``
is close to linearly separable (98%+ reachable, like MNIST); ``cifar_like``
uses heavier noise + class-overlapping prototypes (much harder, mimicking
the paper's CIFAR-10 gap).

LM tasks: a random first-order Markov chain over the vocabulary with a
Zipf-ish stationary marginal — gives next-token structure a model can
learn (CE well below uniform) while being fully deterministic — and,
since the LM-executor PR, REAL text: ``TextSource`` samples fixed-shape
token blocks from the checked-in corpus sample through the self-trained
byte-level BPE tokenizer (``repro.data.encoder``), same purity
contract.

Streaming sources: every generator is a pure function of (seed, split) —
a node in a distributed/federated run, or a serving-traffic generator,
regenerates its data without communication. That contract is now a
small protocol, ``Source``: ``sample(split, n, seed)`` must return the
same arrays for the same arguments, forever. ``PrototypeSource`` is the
generator behind ``mnist_like``/``cifar_like`` (which delegate to it
and return bit-identical arrays to what they always returned);
``ArraySource`` adapts already-materialized arrays (e.g. a task's test
split) to the same protocol so request generators and batch iteration
consume one interface.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Protocol, Tuple, runtime_checkable

import numpy as np


# ---------------------------------------------------------------------------
# Image classification (paper's setting)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ImageTask:
    x_train: np.ndarray      # (N, D) float32 in [0, 1]
    y_train: np.ndarray      # (N,) int32
    x_test: np.ndarray
    y_test: np.ndarray
    num_classes: int
    dim: int


@runtime_checkable
class Source(Protocol):
    """Minimal streaming-source protocol (ROADMAP item 5 start).

    ``sample(split, n, seed)`` returns ``(x, y)`` with ``x`` of shape
    (n, dim) float32 in [0, 1] and ``y`` (n,) int32 — and MUST be a pure
    function of ``(split, n, seed)``: any consumer (a federated node, a
    serving request generator, a replayed benchmark) regenerates the
    exact same arrays without communication. ``split`` is a free-form
    label ("train" / "test" / "serve" / ...) that seeds an independent
    stream per consumer.
    """
    num_classes: int
    dim: int

    def sample(self, split: str, n: int, seed: int = 0
               ) -> Tuple[np.ndarray, np.ndarray]: ...


def _split_rng(seed, split: str, stream_seed: int):
    """Deterministic per-(seed, split, stream) generator: the split label
    is folded in bytewise so distinct labels give independent streams."""
    return np.random.default_rng(
        [int(seed), int(stream_seed)] + list(split.encode("utf-8")))


def _smooth_noise(rng, n, side, ch, scale):
    """Low-frequency noise: upsampled coarse grid (structured, image-like)."""
    coarse = rng.normal(size=(n, ch, side // 4, side // 4)) * scale
    up = coarse.repeat(4, axis=2).repeat(4, axis=3)
    return up.reshape(n, -1)


@dataclasses.dataclass(frozen=True)
class PrototypeSource:
    """The class-prototype generator behind ``mnist_like``/``cifar_like``
    as a streaming ``Source``.

    ``task(n_train, n_test)`` reproduces the classic array-returning
    helpers bit-for-bit (one rng threaded protos -> train -> test, the
    original call sequence). ``sample(split, n, seed)`` draws a fresh
    deterministic batch from the SAME prototypes for any (split, seed) —
    what serving-request generators and streaming consumers use.
    """
    seed: int
    side: int
    ch: int
    num_classes: int
    proto_scale: float
    noise_scale: float
    overlap: bool
    max_shift: int = 3

    @property
    def dim(self) -> int:
        return self.side * self.side * self.ch

    def _protos(self, rng):
        """Class prototypes; consumes ``rng`` exactly like the original
        ``_make_image_task`` preamble (bit-compat depends on it)."""
        protos = _smooth_noise(rng, self.num_classes, self.side, self.ch,
                               self.proto_scale)
        if self.overlap:
            # mix prototypes so classes share structure (harder task)
            mix = rng.dirichlet(np.ones(self.num_classes) * 0.4,
                                size=self.num_classes)
            protos = mix @ protos
        return protos.reshape(self.num_classes, self.ch, self.side,
                              self.side)

    @functools.cached_property
    def _protos_cached(self):
        return self._protos(np.random.default_rng(self.seed))

    def _draw(self, protos_img, n, rng):
        y = rng.integers(0, self.num_classes, size=n).astype(np.int32)
        x = protos_img[y]
        if self.max_shift:
            # translation jitter (MNIST-style position variance) — breaks
            # linear separability while MLPs cope fine
            dx = rng.integers(-self.max_shift, self.max_shift + 1, size=n)
            dy = rng.integers(-self.max_shift, self.max_shift + 1, size=n)
            x = np.stack([np.roll(np.roll(im, a, axis=1), b, axis=2)
                          for im, a, b in zip(x, dx, dy)])
        x = x.reshape(n, self.dim)
        x = x + _smooth_noise(rng, n, self.side, self.ch, self.noise_scale)
        x = x + rng.normal(size=(n, self.dim)) * self.noise_scale * 0.5
        x = 1.0 / (1.0 + np.exp(-x))                     # into [0, 1]
        return x.astype(np.float32), y

    def task(self, n_train, n_test) -> ImageTask:
        """The classic fixed-size task: protos, train and test all drawn
        from ONE threaded rng (the original helpers' exact stream)."""
        rng = np.random.default_rng(self.seed)
        protos_img = self._protos(rng)
        x_tr, y_tr = self._draw(protos_img, n_train, rng)
        x_te, y_te = self._draw(protos_img, n_test, rng)
        return ImageTask(x_tr, y_tr, x_te, y_te, self.num_classes,
                         self.dim)

    def sample(self, split: str, n: int, seed: int = 0):
        """Fresh deterministic draw per (split, seed) — same prototypes,
        independent noise/label stream."""
        return self._draw(self._protos_cached, n,
                          _split_rng(self.seed, split, seed))


@dataclasses.dataclass(frozen=True)
class ArraySource:
    """Already-materialized arrays as a ``Source``: ``sample`` draws a
    deterministic-with-replacement subset per (split, seed). Adapts a
    task's test split (or any labeled array pair) to the streaming
    interface serving-request generators consume."""
    x: np.ndarray
    y: np.ndarray
    num_classes: int

    @property
    def dim(self) -> int:
        return int(self.x.shape[-1])

    def sample(self, split: str, n: int, seed: int = 0):
        idx = _split_rng(0, split, seed).integers(0, len(self.x), size=n)
        return (np.asarray(self.x)[idx],
                np.asarray(self.y)[idx].astype(np.int32))

    def minibatches(self, batch_size, seed):
        """Shuffled minibatch iterator over the arrays (one epoch) — the
        exact stream ``batches`` always produced."""
        rng = np.random.default_rng(seed)
        order = rng.permutation(len(self.x))
        for i in range(0, len(self.x) - batch_size + 1, batch_size):
            j = order[i:i + batch_size]
            yield self.x[j], self.y[j]


def source_of(task: ImageTask, split: str = "test") -> ArraySource:
    """A task's train/test arrays as a streaming ``Source`` (the default
    request-payload source for ``repro.serve``)."""
    if split == "train":
        return ArraySource(task.x_train, task.y_train, task.num_classes)
    return ArraySource(task.x_test, task.y_test, task.num_classes)


def mnist_source(seed=0) -> PrototypeSource:
    """The generator behind ``mnist_like`` as a streaming ``Source``."""
    return PrototypeSource(seed, side=28, ch=1, num_classes=10,
                           proto_scale=2.0, noise_scale=0.8,
                           overlap=False, max_shift=4)


def cifar_source(seed=0) -> PrototypeSource:
    """The generator behind ``cifar_like`` as a streaming ``Source``."""
    return PrototypeSource(seed + 7, side=32, ch=3, num_classes=10,
                           proto_scale=1.0, noise_scale=0.9,
                           overlap=True, max_shift=3)


def mnist_like(seed=0, n_train=6000, n_test=1000):
    """28x28x1, 10 classes, separable but not linearly (MNIST stand-in)."""
    return mnist_source(seed).task(n_train, n_test)


def cifar_like(seed=0, n_train=6000, n_test=1000):
    """32x32x3, 10 classes, overlapping prototypes + heavy noise."""
    return cifar_source(seed).task(n_train, n_test)


def shard_task(task: ImageTask, node: int, num_nodes: int) -> ImageTask:
    """Federated split: node-local training shard, shared test set."""
    idx = np.arange(node, len(task.x_train), num_nodes)
    return dataclasses.replace(task, x_train=task.x_train[idx],
                               y_train=task.y_train[idx])


def batches(x, y, batch_size, seed):
    """Shuffled minibatch index iterator (one epoch) — delegates to the
    ``ArraySource`` streaming interface, same stream as always."""
    yield from ArraySource(np.asarray(x), np.asarray(y),
                           int(np.max(y)) + 1 if len(y) else 0
                           ).minibatches(batch_size, seed)


# ---------------------------------------------------------------------------
# Language modelling (synthetic Markov corpus)
# ---------------------------------------------------------------------------

class MarkovLM:
    """First-order Markov chain with sparse transitions + Zipf marginal."""

    def __init__(self, vocab, seed=0, branching=32):
        rng = np.random.default_rng(seed)
        self.vocab = vocab
        self.branching = branching
        # each token can transition to `branching` successors
        self.succ = rng.integers(0, vocab, size=(vocab, branching))
        w = rng.pareto(1.2, size=(vocab, branching)) + 0.05
        self.probs = (w / w.sum(1, keepdims=True)).astype(np.float64)

    def sample(self, batch, seq_len, seed):
        rng = np.random.default_rng(seed)
        out = np.empty((batch, seq_len), np.int32)
        tok = rng.integers(0, self.vocab, size=batch)
        for t in range(seq_len):
            out[:, t] = tok
            choice = np.array(
                [rng.choice(self.branching, p=self.probs[k]) for k in tok])
            tok = self.succ[tok, choice]
        return out


def lm_batches(vocab, batch, seq_len, steps, seed=0):
    """Yields (batch, seq_len + 1) int32 token blocks for `steps` steps."""
    chain = MarkovLM(min(vocab, 4096), seed)
    for s in range(steps):
        yield chain.sample(batch, seq_len + 1, seed * 100003 + s) % vocab


# ---------------------------------------------------------------------------
# Language modelling (real text through the byte-level BPE pipeline)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TextSource:
    """Real text as a streaming ``Source``: the checked-in corpus,
    tokenized once by the byte-level BPE encoder (``data.encoder``),
    sampled as fixed-shape ``(n, seq_len + 1)`` int32 token blocks.

    ``blocks(split, n, seed)`` — the LM-native sampler — draws ``n``
    random windows from the split's region of the token stream and is a
    pure function of ``(split, n, seed)`` (the ``Source`` contract):
    every node of a distributed run regenerates its batches locally, so
    training data never crosses the hand-off. "train" windows come
    from the leading ``1 - holdout`` fraction of the stream, any other
    split ("val" / "test" / ...) from the held-out tail, so eval never
    sees training positions. ``sample`` adapts the same windows to the
    protocol's ``(x, y)`` shape (x = the window's first ``seq_len``
    tokens, y = the next token) — tokens, not pixels; consumers that
    need the full block use ``blocks``.
    """
    ids: np.ndarray          # (T,) int32 — the tokenized corpus
    encoder: object          # data.encoder.Encoder (vocab/round-trip)
    seq_len: int
    seed: int = 0
    holdout: float = 0.1

    @property
    def num_classes(self) -> int:
        return int(self.encoder.n_vocab)

    @property
    def vocab(self) -> int:
        return self.num_classes

    @property
    def dim(self) -> int:
        return self.seq_len

    def _region(self, split: str) -> np.ndarray:
        cut = int(len(self.ids) * (1.0 - self.holdout))
        return self.ids[:cut] if split == "train" else self.ids[cut:]

    def blocks(self, split: str, n: int, seed: int = 0) -> np.ndarray:
        """(n, seq_len + 1) int32 token windows, deterministic per
        (split, n, seed)."""
        region = self._region(split)
        span = self.seq_len + 1
        if len(region) < span:
            raise ValueError(
                f"split {split!r} holds {len(region)} tokens < "
                f"seq_len + 1 = {span}")
        rng = _split_rng(self.seed, split, seed)
        offs = rng.integers(0, len(region) - span + 1, size=n)
        return region[offs[:, None] + np.arange(span)].astype(np.int32)

    def sample(self, split: str, n: int, seed: int = 0):
        b = self.blocks(split, n, seed)
        return b[:, :-1], b[:, -1].astype(np.int32)


@functools.lru_cache(maxsize=None)
def text_source(vocab: int = 512, seq_len: int = 32,
                seed: int = 0) -> TextSource:
    """The default real-text LM source: BPE encoder trained on the
    checked-in corpus sample, corpus tokenized once (memoized).
    ``vocab`` must cover the encoder's vocabulary (reduced LM configs
    use 512)."""
    from repro.data import encoder as encoder_lib

    enc = encoder_lib.default_encoder(min(vocab, 512))
    if enc.n_vocab > vocab:
        raise ValueError(f"config vocab {vocab} < encoder vocab "
                         f"{enc.n_vocab}")
    ids = np.asarray(enc.encode(encoder_lib.corpus_text()), np.int32)
    return TextSource(ids=ids, encoder=enc, seq_len=seq_len, seed=seed)
