"""Quickstart: train the paper's FF MLP on the synthetic MNIST-like task
and evaluate with both prediction modes, then simulate the PFF schedules.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro import data
from repro.configs.ff_mlp import FFMLPConfig
from repro.core import ff_mlp, pff

# scaled-down paper config (paper: [784, 2000 x4], E=100, S=100)
task = data.mnist_like(n_train=2560, n_test=500)
cfg = FFMLPConfig(
    layer_sizes=(task.dim, 400, 400, 400),
    epochs=60, splits=6,
    neg_mode="random",          # adaptive | fixed | random
    classifier="goodness",      # goodness | softmax
)

print("training FF (sequential chapter schedule)...")
result = pff.train_ff_mlp(cfg, task, probe_every=2, verbose=True)
print(f"\nGoodness prediction accuracy: {result.test_acc:.4f}")

soft_acc = ff_mlp.accuracy(result.params, task.x_test, task.y_test,
                           cfg.num_classes, mode="softmax")
print(f"Softmax head accuracy:        {soft_acc:.4f} "
      "(head trained only when classifier='softmax')")

print("\nPFF schedules (from measured task durations):")
for sched, n in (("sequential", 1), ("single_layer", 4),
                 ("all_layers", 4)):
    sim = pff.simulate_schedule(result.records, sched, n)
    print(f"  {sched:13s} N={n}: {sim.makespan:7.1f}s "
          f"speedup x{sim.speedup:4.2f} utilization {sim.utilization:.2f}")
