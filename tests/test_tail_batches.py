"""Tail-batch coverage: the chapter trainers used to compute
``n_batches = n // batch``, silently discarding up to ``batch - 1``
samples every mini-epoch (worst for Federated PFF, whose per-node shards
are rarely divisible by the batch size). The fix wraps the shuffled
permutation to a whole number of full batches — every sample is
consumed at least once per mini-epoch and batch shapes stay static."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api, data as data_lib, optim
from repro.configs.ff_mlp import FFMLPConfig
from repro.core import ff_mlp


@pytest.mark.parametrize("n,batch", [(100, 64), (130, 64), (640, 64),
                                     (65, 64), (64, 64), (63, 64),
                                     (20, 64)])  # n < batch: tiny shard
def test_epoch_perm_consumes_every_sample(n, batch):
    key = jax.random.PRNGKey(0)
    perm = ff_mlp._epoch_perm(key, 3, n, batch)
    n_batches = ff_mlp._num_batches(n, batch)
    assert n_batches == -(-n // batch)
    assert perm.shape == (n_batches * batch,)
    # every sample appears (wrapping duplicates the first n%batch of the
    # shuffle, it never drops anyone)
    assert set(np.asarray(perm).tolist()) == set(range(n))


def test_epoch_perm_no_pad_when_divisible():
    key = jax.random.PRNGKey(0)
    perm = ff_mlp._epoch_perm(key, 1, 128, 64)
    ref = jax.random.permutation(jax.random.fold_in(key, 1), 128)
    assert bool(jnp.array_equal(perm, ref))


def _tail_grad_params(n):
    """Trains one layer chapter on an n-sample set; returns params."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (n, 32), jnp.float32)
    lp = {"w": jax.random.normal(key, (32, 16), jnp.float32) * 0.1,
          "b": jnp.zeros((16,), jnp.float32)}
    opt = optim.adam_init(lp)
    lrs = jnp.full((2,), 0.01, jnp.float32)
    lp, _ = ff_mlp.train_layer_chapter(
        lp, opt, x, -x, lrs, key, batch=64, epochs=2, theta=2.0,
        peer_w=0.0, impl="ref")
    return lp


@pytest.mark.parametrize("n", [100, 65, 20])
def test_train_layer_chapter_tail_batch_trains(n):
    """n % 64 != 0 must still run the full ceil(n/64) batches and
    produce finite, changed weights."""
    lp = _tail_grad_params(n)
    assert bool(jnp.all(jnp.isfinite(lp["w"])))
    assert float(jnp.abs(lp["w"]).max()) > 0


def test_train_ff_mlp_non_divisible_dataset():
    """End-to-end trainer on n_train % batch != 0 (the federated shard
    shape): still learns well above chance."""
    task = data_lib.mnist_like(n_train=2500, n_test=200)   # 2500 % 64 = 4
    cfg = FFMLPConfig(layer_sizes=(784, 300), epochs=60, splits=4,
                      neg_mode="random", classifier="goodness",
                      batch_size=64, seed=0)
    res = api.fit(cfg, task)
    # same bar as test_pff.test_federated_trains_on_shards (one hidden
    # layer learns weakly on the synthetic task; chance is 0.1)
    assert res.test_acc > 0.15


def test_train_head_chapter_tail_batch():
    key = jax.random.PRNGKey(1)
    feats = jax.random.normal(key, (70, 24), jnp.float32)
    y = jax.random.randint(key, (70,), 0, 10)
    head = {"w": jnp.zeros((24, 10), jnp.float32),
            "b": jnp.zeros((10,), jnp.float32)}
    opt = optim.adam_init(head)
    lrs = jnp.full((1,), 0.01, jnp.float32)
    head, _ = ff_mlp.train_head_chapter(head, opt, feats, y, lrs, key,
                                        batch=64, epochs=1)
    # 2 batches ran (not 1): with truncation the second (wrapped) batch
    # would never contribute and b would move less; just assert movement
    assert bool(jnp.all(jnp.isfinite(head["w"])))
    assert float(jnp.abs(head["b"]).max()) > 0


def test_train_layer_chapter_perf_opt_tail_batch():
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (70, 32), jnp.float32)
    y = jax.random.randint(key, (70,), 0, 10)
    lp = {"w": jax.random.normal(key, (32, 16), jnp.float32) * 0.1,
          "b": jnp.zeros((16,), jnp.float32)}
    head = {"w": jnp.zeros((16, 10), jnp.float32),
            "b": jnp.zeros((10,), jnp.float32)}
    opt, opt_h = optim.adam_init(lp), optim.adam_init(head)
    lrs = jnp.full((1,), 0.01, jnp.float32)
    lp, head, _, _ = ff_mlp.train_layer_chapter_perf_opt(
        lp, head, opt, opt_h, x, y, lrs, key, batch=64, epochs=1)
    assert bool(jnp.all(jnp.isfinite(lp["w"])))
    assert float(jnp.abs(head["b"]).max()) > 0
