"""recurrentgemma-2b — RG-LRU + local attention, 1:2 [arXiv:2402.19427].

26 layers, pattern (rglru, rglru, local_attn) x 8 + (rglru, rglru),
d_model=2560, 10 heads (MQA kv=1, head_dim=256), d_ff=7680, vocab=256000.
"""
from repro.configs.base import ModelConfig, RGLRUConfig, register

CONFIG = register(ModelConfig(
    name="recurrentgemma-2b",
    arch_type="hybrid",
    num_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv=1,
    head_dim=256,
    d_ff=7680,
    vocab=256000,
    groups=(
        (("rglru", "rglru", "local_attn"), 8),
        (("rglru", "rglru"), 1),
    ),
    rglru=RGLRUConfig(lru_width=2560, conv_width=4, window=2048),
    act="gelu",
    logit_softcap=30.0,
    tie_embeddings=True,
    source="arXiv:2402.19427 (Griffin / RecurrentGemma)",
))
