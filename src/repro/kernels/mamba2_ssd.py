"""Chunked SSD (Mamba-2 state-space duality) Pallas kernel.

Implements one full sequence scan: grid = (B, nc) with the chunk index
innermost, so the (H, hd, N) inter-chunk state lives in VMEM scratch and
is carried across chunk steps — the kernel IS the sequential scan, with
the quadratic dual form giving the MXU dense (L x L) work per chunk.

Per chunk (L = chunk length):
  cums   = cumsum(dA)                          (L, H)
  y_intra[i] = sum_{j<=i} (c_i . b_j) exp(cums_i - cums_j) xbar_j
  y_inter[i] = (c_i . h) * exp(cums_i)         carried state h
  h     <- h * exp(cums_L) + sum_j exp(cums_L - cums_j) b_j xbar_j

VMEM budget per step (defaults L=128, H<=64, hd=64, N=128):
  xbar (L, H, hd) f32 0.5 MB  +  decay (L, L, H) 4 MB  +  state
  (H, hd, N) 2 MB — comfortably inside the ~16 MB VMEM of a v5e core.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(dA_ref, xbar_ref, b_ref, c_ref, y_ref, hT_ref, h_scr, *, nc):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr[...])

    dA = dA_ref[0].astype(jnp.float32)            # (L, H)
    xbar = xbar_ref[0].astype(jnp.float32)        # (L, H, hd)
    b = b_ref[0].astype(jnp.float32)              # (L, N)
    c = c_ref[0].astype(jnp.float32)              # (L, N)
    L = dA.shape[0]

    cums = jnp.cumsum(dA, axis=0)                 # (L, H)
    seg = cums[:, None, :] - cums[None, :, :]     # (L, L, H)
    ii = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    decay = jnp.where((ii >= jj)[..., None], jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(
        c, b, (((1,), (1,)), ((), ())))           # (L, L)
    y = jnp.einsum("ij,ijh,jhd->ihd", scores, decay, xbar)

    h = h_scr[...]                                # (H, hd, N)
    decay_in = jnp.exp(cums)                      # (L, H)
    y = y + jnp.einsum("in,hdn,ih->ihd", c, h, decay_in)
    y_ref[0] = y.astype(y_ref.dtype)

    last = cums[-1]                               # (H,)
    decay_out = jnp.exp(last[None, :] - cums)     # (L, H)
    st = jnp.einsum("jh,jn,jhd->hdn", decay_out, b, xbar)
    h = h * jnp.exp(last)[:, None, None] + st
    h_scr[...] = h

    @pl.when(ic == nc - 1)
    def _emit_state():
        hT_ref[0] = h.astype(hT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mamba2_ssd(xbar, dA, b, c, *, chunk=128, interpret=True):
    """xbar: (B, S, H, hd) = x*dt; dA: (B, S, H) = dt*A (negative);
    b, c: (B, S, N). Returns y: (B, S, H, hd) f32, hT: (B, H, hd, N) f32.
    S must be a chunk multiple (pad upstream — dt=0 rows are inert)."""
    B, S, H, hd = xbar.shape
    N = b.shape[-1]
    L = min(chunk, S)
    assert S % L == 0, (S, L)
    nc = S // L

    kernel = functools.partial(_kernel, nc=nc)
    y, hT = pl.pallas_call(
        kernel,
        grid=(B, nc),
        in_specs=[
            pl.BlockSpec((1, L, H), lambda ib, ic: (ib, ic, 0)),
            pl.BlockSpec((1, L, H, hd), lambda ib, ic: (ib, ic, 0, 0)),
            pl.BlockSpec((1, L, N), lambda ib, ic: (ib, ic, 0)),
            pl.BlockSpec((1, L, N), lambda ib, ic: (ib, ic, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, L, H, hd), lambda ib, ic: (ib, ic, 0, 0)),
            pl.BlockSpec((1, H, hd, N), lambda ib, ic: (ib, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, H, hd, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((H, hd, N), jnp.float32)],
        interpret=interpret,
    )(dA, xbar, b, c)
    return y, hT
