"""Training launcher.

Two modes:
  * ``--paper-mlp``: the paper's own experiment — FF MLP on the synthetic
    image task with a PFF schedule.
  * ``--arch <id>``: FF-train a (reduced, unless --full) assigned
    architecture on the synthetic LM corpus. On this CPU container the
    reduced configs run for real; the full configs are exercised by
    ``dryrun.py``.

``--arch`` with ``--chapters N`` switches from the joint FF step to the
paper's CHAPTER schedule on the real-text BPE source (``data.
text_source``) — sequentially, or on the real executor across
``--nodes`` devices (``--backend executor``).

Examples:
  PYTHONPATH=src python -m repro.launch.train --paper-mlp \
      --neg-mode random --classifier goodness --epochs 60 --splits 10
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --steps 50 --batch 8 --seq 128
  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
      --chapters 4 --backend executor --schedule single_layer --nodes 4
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro import api, checkpoint, data as data_lib, optim
from repro.configs import get_config
from repro.configs.ff_mlp import FFMLPConfig
from repro.core import pff_dag, train as train_lib
from repro.kernels import ops
from repro.models import transformer
from repro.obs import export as obs_export, trace as obs_trace


def run_paper_mlp(args):
    task = (data_lib.cifar_like if args.cifar else data_lib.mnist_like)(
        seed=args.seed, n_train=args.n_train, n_test=args.n_test)
    sizes = (task.dim,) + tuple(args.hidden for _ in range(args.layers))
    cfg = FFMLPConfig(
        layer_sizes=sizes, epochs=args.epochs, splits=args.splits,
        neg_mode=args.neg_mode or FFMLPConfig.neg_mode,
        classifier=args.classifier,
        goodness_fn=args.goodness_fn, batch_size=args.batch,
        kernel_impl=args.kernel_impl, seed=args.seed)
    backend = args.backend
    if backend == "sequential" and args.schedule == "federated":
        backend = "federated"          # pre-facade CLI spelling
    t0 = time.time()
    res = api.fit(cfg, task, backend=backend, schedule=args.schedule,
                  num_nodes=args.nodes, probe_every=args.probe,
                  verbose=True,
                  trace=getattr(args, "tracer", obs_trace.NOOP))
    wall = time.time() - t0
    acc = f"test acc {res.test_acc:.4f}" if res.test_acc is not None else ""
    print(f"\n[{backend}] {acc}  wall {wall:.1f}s")
    if res.makespan is not None:
        speed = (f" speedup={res.speedup:5.2f}x "
                 f"util={res.utilization:.2f}"
                 if res.speedup is not None else "")
        print(f"  {res.schedule} N={res.num_nodes}: "
              f"makespan={res.makespan:8.2f}s{speed}")
    if res.records:
        for sched, n in (("sequential", 1), ("single_layer", args.nodes),
                         ("all_layers", args.nodes)):
            sim = api.simulate(res, sched, n)
            print(f"  {sched:13s} N={n}: time={sim.makespan:8.1f}s "
                  f"speedup={sim.speedup:5.2f}x "
                  f"util={sim.utilization:.2f}  (simulated)")
    return res


def run_lm_chapters(args, cfg):
    """LM chapter schedule on real text (``--chapters N``): per-block
    train tasks + a per-chapter head task, sequentially
    (``--backend sequential``) or on the real executor across
    ``--nodes`` devices (``--backend executor --schedule ...``) —
    the ``api.fit`` invocation the README documents."""
    tracer = getattr(args, "tracer", obs_trace.NOOP)
    source = data_lib.text_source(vocab=cfg.vocab, seq_len=args.seq,
                                  seed=args.seed)
    t0 = time.time()
    res = api.fit(cfg, source, backend=args.backend,
                  schedule=args.schedule, num_nodes=args.nodes,
                  chapters=args.chapters,
                  steps_per_chapter=args.steps_per_chapter,
                  batch=args.batch, seq=args.seq, lr=args.lr,
                  head_lr=args.head_lr,
                  trace=tracer if tracer.enabled else None)
    wall = time.time() - t0
    print(f"\n[{args.backend}] {res.schedule} N={res.num_nodes}: "
          f"chapters={args.chapters} eval_ce={res.eval_ce:.4f} "
          f"makespan={res.makespan:.2f}s wall={wall:.1f}s")
    if args.ckpt:
        checkpoint.save(args.ckpt, res.params,
                        step=args.chapters * args.steps_per_chapter,
                        tracer=tracer)
        print("saved", args.ckpt)
    return res.params


def run_lm(args):
    tracer = getattr(args, "tracer", obs_trace.NOOP)
    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    if args.neg_mode:
        cfg = dataclasses.replace(
            cfg, ff=dataclasses.replace(cfg.ff, neg_mode=args.neg_mode))
    if args.chapters:
        return run_lm_chapters(args, cfg)
    key = jax.random.PRNGKey(args.seed)
    params = transformer.init(key, cfg)
    opt = optim.adam_init(params)
    make = (train_lib.make_bp_train_step if args.baseline
            else train_lib.make_ff_train_step)
    step_fn = jax.jit(make(cfg, lr=args.lr))

    aux = None
    if cfg.enc_dec:
        aux = jax.random.normal(key, (args.batch, cfg.enc_seq,
                                      cfg.d_model), cfg.dtype)
    elif cfg.vision_tokens:
        aux = jax.random.normal(key, (args.batch, cfg.vision_tokens,
                                      cfg.d_model), cfg.dtype)

    t0 = time.time()
    with tracer.span("train:lm", arch=args.arch, steps=args.steps):
        for i, tokens in enumerate(data_lib.lm_batches(
                cfg.vocab, args.batch, args.seq, args.steps, args.seed)):
            batch = {"tokens": jnp.asarray(tokens)}
            if aux is not None:
                batch["aux"] = aux
            params, opt, metrics = step_fn(params, opt, batch, i + 1)
            if (i + 1) % args.log_every == 0:
                m = {k: round(float(v), 4) for k, v in metrics.items()}
                print(f"step {i + 1}: {m}  ({time.time() - t0:.1f}s)")
    if args.ckpt:
        checkpoint.save(args.ckpt, params, step=args.steps,
                        tracer=tracer)
        print("saved", args.ckpt)
    return params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper-mlp", action="store_true")
    ap.add_argument("--cifar", action="store_true")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--baseline", action="store_true",
                    help="backprop baseline instead of FF")
    # choices sourced from the live registries / dispatch tables, so
    # --help stays truthful when strategies are (un)registered
    ap.add_argument("--backend", default="sequential",
                    choices=list(api.BACKENDS),
                    help="api.fit backend (--paper-mlp): sequential "
                         "trainer, event simulator, real multi-device "
                         "executor, federated shards, or pod pipeline")
    ap.add_argument("--schedule", default="all_layers",
                    choices=list(pff_dag.SCHEDULES))
    ap.add_argument("--neg-mode", default=None,
                    choices=[None] + list(api.negatives.names()))
    ap.add_argument("--classifier", default="goodness",
                    choices=list(api.classifier.names()))
    ap.add_argument("--goodness-fn", default="sumsq",
                    choices=list(api.goodness.names()))
    ap.add_argument("--kernel-impl", default="auto",
                    choices=list(ops.FF_DENSE_IMPLS),
                    help="ops.ff_dense impl (choices live from the "
                         "kernel registry): auto = the tuning table's "
                         "measured winner per shape when populated "
                         "(make tune-smoke / REPRO_TUNE_TABLE), else "
                         "the platform default")
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--hidden", type=int, default=500)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--epochs", type=int, default=60)
    ap.add_argument("--splits", type=int, default=10)
    ap.add_argument("--n-train", type=int, default=4032)
    ap.add_argument("--n-test", type=int, default=1000)
    ap.add_argument("--probe", type=int, default=0)
    ap.add_argument("--chapters", type=int, default=0,
                    help="--arch mode: run the LM CHAPTER schedule for "
                         "this many chapters on the real-text BPE "
                         "source (0 = the joint FF step on the "
                         "synthetic corpus); combine with --backend "
                         "sequential|executor and --schedule/--nodes")
    ap.add_argument("--steps-per-chapter", type=int, default=8,
                    help="per-task step budget of the chapter schedule")
    ap.add_argument("--head-lr", type=float, default=None,
                    help="chapter-head learning rate (default: --lr)")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record an execution trace (repro.obs) and "
                         "export it here after the run")
    ap.add_argument("--trace-format", default="chrome",
                    choices=list(obs_export.names()),
                    help="trace exporter (choices live from the "
                         "repro.obs exporter registry); chrome loads "
                         "in Perfetto / chrome://tracing")
    args = ap.parse_args()
    args.tracer = (obs_trace.Tracer(meta={"launcher": "train"})
                   if args.trace else obs_trace.NOOP)
    if args.paper_mlp:
        run_paper_mlp(args)
    elif args.arch:
        run_lm(args)
    else:
        ap.error("need --paper-mlp or --arch")
    if args.tracer.enabled:
        obs_export.export(args.tracer, args.trace,
                          format=args.trace_format)
        print(f"trace: {args.tracer.span_count()} spans -> {args.trace} "
              f"({args.trace_format})")


if __name__ == "__main__":
    main()
