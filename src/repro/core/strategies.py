"""Strategy registries for the FF-MLP training facade (``repro.api``).

The paper's variation axes — HOW negatives are generated (AdaptiveNEG /
FixedNEG / RandomNEG), WHAT per-layer objective is trained (sum-of-squares
goodness vs the Performance-Optimized local-head loss, §4.4) and WHICH
classifier produces label scores (accumulated goodness vs the softmax
head) — used to be string-``if`` chains spread across ``ff_mlp.py``,
``pff.py`` and ``pff_exec.py``. They are now three small registries of
looked-up callables sharing one signature each, so the sequential
trainer, the simulator and the real executor all consume the same
strategy objects, and new strategies plug in without touching the
drivers:

    from repro import api
    api.register_negatives("my_neg", my_fn)
    cfg = FFMLPConfig(neg_mode="my_neg", ...)
    api.fit(cfg, task)

This module sits BELOW ``ff_mlp``/``pff``/``pff_exec`` in the import
graph: it defines the registry machinery and the negative-sample
builtins (which only need ``repro.core.ff``); the goodness and
classifier builtins close over ``ff_mlp``'s jitted trainers and are
registered at the bottom of ``ff_mlp.py``. Importing this module pulls
in NO jax — ``repro.core.ff`` is imported lazily inside the builtin
strategy bodies — because ``repro.obs.export`` reuses ``Registry`` and
the obs package must stay analyzable offline where jax is absent.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional


class Registry:
    """A tiny name -> strategy map with helpful lookup errors."""

    def __init__(self, kind: str):
        self.kind = kind
        self._entries = {}

    def register(self, name: str, entry, *, overwrite: bool = False):
        if not overwrite and name in self._entries:
            raise ValueError(
                f"{self.kind} strategy {name!r} already registered "
                "(pass overwrite=True to replace)")
        self._entries[name] = entry
        return entry

    def unregister(self, name: str):
        """Remove a strategy (no-op if absent) — mainly for tests and
        interactive experimentation."""
        self._entries.pop(name, None)

    def get(self, name: str):
        try:
            return self._entries[name]
        except KeyError:
            raise KeyError(
                f"unknown {self.kind} strategy {name!r}; registered: "
                f"{', '.join(self.names())}") from None

    def names(self):
        return tuple(sorted(self._entries))

    def __contains__(self, name):
        return name in self._entries

    def __iter__(self):
        return iter(self.names())


# ---------------------------------------------------------------------------
# Negatives: fn(key, cfg, params, x, y, scores) -> (N, D) overlaid images
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class NegativesStrategy:
    """How negative samples are (re)generated.

    fn(key, cfg, params, x, y, scores) -> (N, D) label-overlaid images
    (the raw, un-normalized overlay; callers apply the inter-layer
    length normalization). ``params`` and ``scores`` (the (N, C)
    per-class score matrix) are the live model ONLY when
    ``needs_scores`` (``params`` is guaranteed to carry the trained
    ``"layers"`` stack; auxiliary groups like the softmax head may be
    absent on the executor); both are None on the very first chapter (before
    any model exists — strategies must degrade gracefully then) and in
    key-only per-node regeneration. A strategy whose ``fn`` reads
    ``params`` or ``scores`` MUST set ``needs_scores=True`` — this is
    what lets the executor regenerate key-only negatives locally per
    node without shipping the model.

    regenerates: whether a per-chapter ``neg_gen`` task exists at all
    (FixedNEG generates once and never refreshes).
    needs_scores: whether regeneration needs the full current model's
    class scores. This drives the executor's publish semantics: a
    score-needing strategy is generated ONCE and published along the
    chapter DAG (the paper's Single-Layer serialization), while a
    key-only strategy is regenerated locally per node, bit-identically,
    by PRNG determinism.
    """
    name: str
    fn: Callable
    regenerates: bool = True
    needs_scores: bool = False


@dataclasses.dataclass(frozen=True)
class GoodnessStrategy:
    """What each layer trains during its chapter task.

    All callables share one signature built around an opaque per-layer
    ``state`` tuple whose first element is always the layer's param dict
    (so drivers can hand activations/weights along the DAG without
    knowing the strategy):

      get_state(params, opt, k)          -> state
      set_state(params, opt, k, state)   (writes state back)
      train_chapter(state, acts, extras, lrs, key, *, cfg, epochs)
                                         -> state
      export(states)                     -> partial params dict

    ``acts`` are the activation tensors that flow layer-to-layer (each
    advanced with ``ff_mlp.fwd_norm``); ``extras`` are per-chapter
    constants (e.g. labels) that do not.

    uses_negatives: False means the strategy trains on labeled data only
    (no pos/neg pair, no ``neg_gen`` tasks — the paper's §4.4 path).
    eval_mode(cfg): the classifier-registry entry used for final
    evaluation. init_extras(key, cfg), when set, returns extra parameter
    groups the strategy trains besides the layers (e.g. the §4.4 local
    heads) — merged into the params dict by ``ff_mlp.init``.
    """
    name: str
    uses_negatives: bool
    get_state: Callable
    set_state: Callable
    train_chapter: Callable
    export: Callable
    eval_mode: Callable
    init_extras: Optional[Callable] = None


@dataclasses.dataclass(frozen=True)
class ClassifierStrategy:
    """How (B, C) label scores are produced at prediction time.

    scores(params, x, *, num_classes, impl) -> (B, C); higher = more
    predicted. ``trains_head`` marks strategies that require the
    dedicated softmax-head chapter task during training.
    ``requires_goodness`` (optional) names the goodness strategy whose
    parameters this classifier reads (e.g. the Performance-Optimized
    local heads) — ``api.fit`` validates the pairing.
    """
    name: str
    scores: Callable
    trains_head: bool = False
    requires_goodness: Optional[str] = None


negatives = Registry("negatives")
goodness = Registry("goodness")
classifier = Registry("classifier")


def register_negatives(name, fn, *, regenerates=True, needs_scores=False,
                       overwrite=False):
    """Public hook: plug a new negative-sample strategy into the facade."""
    return negatives.register(
        name, NegativesStrategy(name, fn, regenerates, needs_scores),
        overwrite=overwrite)


def register_goodness(name, strategy, *, overwrite=False):
    return goodness.register(name, strategy, overwrite=overwrite)


def register_classifier(name, scores, *, trains_head=False,
                        requires_goodness=None, overwrite=False):
    return classifier.register(
        name, ClassifierStrategy(name, scores, trains_head,
                                 requires_goodness),
        overwrite=overwrite)


# ---------------------------------------------------------------------------
# Builtin negative-sample strategies (paper §4.2)
# ---------------------------------------------------------------------------

def _random_negatives(key, cfg, params, x, y, scores):
    """RandomNEG: uniform over the C-1 wrong labels, fresh each chapter."""
    from repro.core import ff
    labels = ff.random_wrong_labels(key, y, cfg.num_classes)
    return ff.overlay_label(x, labels, cfg.num_classes)


def _adaptive_negatives(key, cfg, params, x, y, scores):
    """AdaptiveNEG: confusable wrong labels from the model's own class
    scores; falls back to RandomNEG before a model exists (chapter 0),
    which keeps the initial negatives bit-identical across strategies."""
    if scores is None:
        return _random_negatives(key, cfg, params, x, y, scores)
    from repro.core import ff
    labels = ff.adaptive_wrong_labels(scores, y, key=key)
    return ff.overlay_label(x, labels, cfg.num_classes)


register_negatives("random", _random_negatives)
register_negatives("adaptive", _adaptive_negatives, needs_scores=True)
# FixedNEG = RandomNEG sampled once, never refreshed
register_negatives("fixed", _random_negatives, regenerates=False)
