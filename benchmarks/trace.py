"""Trace-smoke: the ``repro.obs`` subsystem exercised end to end.

One traced N=4 executor run + one traced train-while-serve run, pushed
through the exporter registry and the analyzer, with hard gates:

  1. two-run inequality — critical path (traced+blocked run A) <=
     measured makespan (warm untraced run B) <= serial execution
     (max of run A's summed task durations and a measured warm N=1
     run C — on a shared-core container the parallel run contends for
     cores the blocked per-task measurements had to themselves, so the
     measured serial run is the honest upper bound). Run A blocks
     after every task so span durations are real device time; run B
     keeps the async overlap, so its wall clock is the honest makespan
     (same observer-effect protocol as ``benchmarks/pff_exec.py``).
  2. hand-off attribution — the analyzer's ``prefetch_hit`` event count
     (cost OFF the critical path) must equal the executor's own
     ``handoff["prefetch_hits"]`` counter from the same run.
  3. bit-exactness with tracing ON — the traced executor's final
     weights must be bit-identical to the sequential trainer's (the
     PR 5 oracle must not notice the tracer).
  4. exporter round-trip — the Chrome export must be loadable
     (Perfetto/chrome://tracing schema: X/i/M events, µs timestamps)
     and the JSONL export must reload into an analyzer-equal trace.
  5. disabled-tracer overhead < 2% — measured as (NOOP call cost x the
     number of trace records a real traced run produces) against run
     B's makespan. A wall-clock A/B on a 2-core container is noise at
     the 2% level, so the gate multiplies out the microbenchmark; the
     wall-clock ratio is recorded alongside for the curious.
  6. serve leg — a traced ``api.serve`` train-while-serve run
     (non-blocking tracer: overlap intact) must record admission /
     batch-form / score / swap-install spans on the SAME clock as the
     executor's task spans, with zero consistency violations.

Writes ``BENCH_trace.json`` (gates + makespan decomposition) and
``BENCH_trace_timeline.json`` (the Chrome/Perfetto timeline of run A).
Needs >= 4 devices (``make trace-smoke`` fakes them via XLA_FLAGS).
"""
from __future__ import annotations

import json
import os
import sys
import time

if "jax" not in sys.modules:                       # pragma: no cover
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax

from repro import api, data as data_lib
from repro.configs.ff_mlp import FFMLPConfig
from repro.core import pff_exec
from repro.obs import analyze as obs_analyze
from repro.obs import export as obs_export
from repro.obs import trace as obs_trace

OVERHEAD_GATE = 0.02        # disabled tracer must cost < 2% of makespan


def _noop_call_cost_s(iters=200_000):
    """Amortized cost of one disabled-tracer touch: the span context
    manager + an event + an ``enabled`` guard + ``now()`` — the
    superset of what any instrumented hot path does per record."""
    noop = obs_trace.NOOP
    t0 = time.perf_counter()
    for _ in range(iters):
        with noop.span("x", a=1):
            pass
        noop.event("y")
        if noop.enabled:
            noop.add_span("z", 0.0)
        noop.now()
    return (time.perf_counter() - t0) / iters


def _validate_chrome(path):
    """Schema checks a Perfetto/chrome://tracing load would apply."""
    fails = []
    with open(path) as f:
        doc = json.load(f)
    evs = doc.get("traceEvents")
    if not isinstance(evs, list) or not evs:
        return [f"{path}: no traceEvents array"]
    phases = {e.get("ph") for e in evs}
    if "X" not in phases:
        fails.append(f"{path}: no complete (ph=X) events")
    for e in evs:
        if e.get("ph") == "X":
            if not (isinstance(e.get("ts"), (int, float))
                    and isinstance(e.get("dur"), (int, float))
                    and e["dur"] >= 0):
                fails.append(f"{path}: bad X event {e.get('name')!r}")
                break
            if not (isinstance(e.get("pid"), int)
                    and isinstance(e.get("tid"), int)):
                fails.append(f"{path}: X event without int pid/tid")
                break
    if not any(e.get("ph") == "M" and e.get("name") == "process_name"
               for e in evs):
        fails.append(f"{path}: no process_name metadata events")
    return fails


def run(quick=True, out_path=None):
    root = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        os.pardir)
    if out_path is None:
        out_path = os.path.join(root, "BENCH_trace.json")
    timeline_path = os.path.join(os.path.dirname(out_path),
                                 "BENCH_trace_timeline.json")
    n_dev = len(jax.devices())
    print(f"devices: {n_dev} x {jax.default_backend()}")
    if n_dev < 4:
        print(f"only {n_dev} device(s) — keeping existing "
              f"{os.path.normpath(out_path)} (run `make trace-smoke` "
              "for the full measurement)")
        return {"failures": [], "rows": [],
                "note": f"skipped: needs 4 devices, found {n_dev}"}

    n_train, splits, epochs, sizes = (
        (1000, 8, 8, (784, 256, 256, 256, 256)) if quick
        else (4000, 16, 16, (784, 512, 512, 512, 512)))
    cfg = FFMLPConfig(layer_sizes=sizes, epochs=epochs, splits=splits,
                      neg_mode="random", classifier="goodness",
                      batch_size=64, seed=0)
    task = data_lib.mnist_like(n_train=n_train, n_test=500)
    failures = []

    # sequential oracle (weight stream the traced executor must match)
    ref = api.fit(cfg, task, backend="sequential")

    # --- two-run protocol on one executor (shared jit caches) ----------
    ex = pff_exec.PFFExecutor(cfg, task, "all_layers", 4)
    ex.run()                                       # compile warm-up
    tracer = obs_trace.Tracer(meta={"bench": "trace-smoke"})
    traced = ex.run(trace=tracer)                  # run A: blocked+traced
    t_wall0 = time.perf_counter()
    timed = ex.run()                               # run B: warm, untraced
    wall_b = time.perf_counter() - t_wall0
    ex1 = pff_exec.PFFExecutor(cfg, task, "sequential", 1)
    ex1.run()                                      # serial warm-up
    serial = ex1.run()                             # run C: serial bound

    if not pff_exec.params_bit_equal(ref.params, traced.params):
        failures.append("traced executor weight stream diverged from "
                        "the sequential trainer (tracing broke "
                        "bit-exactness)")

    analysis = obs_analyze.analyze(tracer,
                                   measured_makespan=timed.makespan)
    failures += obs_analyze.check_invariants(
        analysis, timed.makespan, serial_makespan=serial.makespan)

    hits_events = analysis.handoff["prefetch_hits"]
    hits_counter = traced.handoff["prefetch_hits"]
    if hits_events != hits_counter:
        failures.append(
            f"analyzer saw {hits_events} prefetch_hit events but the "
            f"executor counted {hits_counter} prefetch hits")
    if analysis.handoff["off_critical_path"] != hits_counter:
        failures.append(
            f"off-critical-path transfer attribution "
            f"{analysis.handoff['off_critical_path']} != prefetch-hit "
            f"counter {hits_counter}")

    # --- exporter round-trips ------------------------------------------
    obs_export.export(tracer, timeline_path, format="chrome")
    failures += _validate_chrome(timeline_path)
    jsonl_path = os.path.join(os.path.dirname(out_path),
                              ".trace_roundtrip.jsonl")
    obs_export.export(tracer, jsonl_path, format="jsonl")
    reloaded = obs_export.load_jsonl(jsonl_path)
    re_analysis = obs_analyze.analyze(reloaded,
                                      measured_makespan=timed.makespan)
    if re_analysis.critical_path != analysis.critical_path or \
            abs(re_analysis.critical_path_s - analysis.critical_path_s) \
            > 1e-9:
        failures.append("JSONL round-trip changed the analysis "
                        "(lossy serialization)")
    os.remove(jsonl_path)

    # --- disabled-tracer overhead gate ---------------------------------
    n_records = (tracer.span_count() + len(tracer.events)
                 + len(tracer.counters))
    per_call = _noop_call_cost_s()
    implied = per_call * n_records
    overhead_frac = implied / timed.makespan if timed.makespan else 0.0
    if overhead_frac >= OVERHEAD_GATE:
        failures.append(
            f"disabled-tracer overhead {overhead_frac:.2%} "
            f"({n_records} records x {per_call * 1e9:.0f}ns) breaches "
            f"the {OVERHEAD_GATE:.0%} gate")

    print(f"trace run A (blocked): {analysis.makespan:.3f}s, "
          f"{tracer.span_count()} spans, {len(tracer.events)} events")
    print(f"run B (untraced, warm): makespan {timed.makespan:.3f}s "
          f"(wall {wall_b:.3f}s); run C (serial N=1): "
          f"{serial.makespan:.3f}s")
    print(f"critical path {analysis.critical_path_s:.3f}s <= "
          f"makespan {timed.makespan:.3f}s <= serial "
          f"{max(analysis.sum_task_s, serial.makespan):.3f}s  "
          f"[{'OK' if not failures else 'CHECK FAILURES'}]")
    print(f"handoff: {analysis.handoff}")
    print(f"noop overhead: {n_records} records x "
          f"{per_call * 1e9:.0f}ns = {implied * 1e3:.3f}ms "
          f"({overhead_frac:.3%} of makespan)")

    # --- serve leg: combined mode on one clock, overlap intact ---------
    serve_tracer = obs_trace.Tracer(block_tasks=False,
                                    meta={"bench": "trace-smoke-serve"})
    sres = api.serve(cfg, task, traffic="uniform", schedule="all_layers",
                     num_nodes=4, rate=300.0, trace=serve_tracer)
    serve_names = {s.name for s in serve_tracer.snapshot()}
    for need in ("serve:score", "serve:swap_install", "serve:batch_form",
                 "task:train", "run"):
        if need not in serve_names:
            failures.append(f"serve-leg trace missing {need!r} spans "
                            f"(got {sorted(serve_names)})")
    if sres.slo["consistency_violations"]:
        failures.append(
            f"{sres.slo['consistency_violations']} consistency "
            f"violations in the traced serve leg")
    print(f"serve leg: {sres.slo['requests']} req, "
          f"{sres.slo['swaps']} swaps, "
          f"{sres.slo['consistency_violations']} violations, "
          f"span kinds {sorted(serve_names)}")

    results = {
        "config": {"n_train": n_train, "splits": splits,
                   "epochs": epochs, "layer_sizes": list(sizes),
                   "devices": n_dev, "backend": jax.default_backend(),
                   "cpu_count": os.cpu_count()},
        "protocol": ("run A traced+blocked (durations/critical path), "
                     "run B warm untraced (measured makespan), run C "
                     "warm serial N=1 (contention-honest upper bound); "
                     "gate cp_A <= makespan_B <= max(sum_A, "
                     "makespan_C)"),
        "analysis": analysis.to_dict(),
        "measured_makespan_s": timed.makespan,
        "serial_makespan_s": serial.makespan,
        "traced_makespan_s": analysis.makespan,
        "decomposition": analysis.decomposition,
        "noop_overhead": {
            "records": n_records,
            "per_call_ns": per_call * 1e9,
            "implied_s": implied,
            "fraction_of_makespan": overhead_frac,
            "gate": OVERHEAD_GATE,
        },
        "serve": {"slo": sres.slo,
                  "span_names": sorted(serve_names)},
        "timeline": os.path.basename(timeline_path),
        "failures": failures,
    }
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {os.path.normpath(out_path)} and "
          f"{os.path.normpath(timeline_path)}")
    return results
