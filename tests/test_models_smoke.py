"""Per-arch smoke tests (deliverable f): reduced variant of each family
runs one forward + one FF train step on CPU; output shapes + no NaNs.
Decode consistency: prefill + one serve_step must match the full forward.
"""
import jax
import jax.numpy as jnp
import pytest

from repro import optim
from repro.configs import get_config, list_configs
from repro.core import train as train_lib
from repro.models import transformer

ARCHS = [a for a in list_configs()]


def _batch(cfg, key, B=2, S=24):
    batch = {"tokens": jax.random.randint(key, (B, S + 1), 0, cfg.vocab)}
    if cfg.enc_dec:
        batch["aux"] = jax.random.normal(
            key, (B, cfg.enc_seq, cfg.d_model), cfg.dtype)
    elif cfg.vision_tokens:
        batch["aux"] = jax.random.normal(
            key, (B, cfg.vision_tokens, cfg.d_model), cfg.dtype)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_finite(arch, key):
    cfg = get_config(arch).reduced()
    params = transformer.init(key, cfg)
    B, S = 2, 24
    batch = _batch(cfg, key, B, S)
    logits, aux_loss = transformer.forward(
        params, cfg, batch["tokens"][:, :-1], aux=batch.get("aux"))
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux_loss))


@pytest.mark.parametrize("arch", ARCHS)
def test_ff_train_step(arch, key):
    cfg = get_config(arch).reduced()
    params = transformer.init(key, cfg)
    opt = optim.adam_init(params)
    batch = _batch(cfg, key)
    step_fn = jax.jit(train_lib.make_ff_train_step(cfg, lr=1e-3))
    p2, o2, metrics = step_fn(params, opt, batch, 1)
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(p2))
    assert all(bool(jnp.isfinite(v)) for v in metrics.values())
    # params must actually change
    delta = sum(float(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32)).max())
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(p2)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch, key):
    cfg = get_config(arch).reduced()
    params = transformer.init(key, cfg)
    B, S = 2, 16
    batch = _batch(cfg, key, B, S)
    tokens = batch["tokens"]
    logits_full, _ = transformer.forward(
        params, cfg, tokens, aux=batch.get("aux"), remat=False)
    logits_pre, caches = transformer.prefill(
        params, cfg, tokens[:, :S], aux=batch.get("aux"), max_len=S + 4)
    logits_dec, _ = transformer.serve_step(
        params, cfg, caches, tokens[:, S], jnp.int32(S))
    assert float(jnp.abs(logits_pre - logits_full[:, :S]).max()) < 2e-2
    assert float(jnp.abs(logits_dec - logits_full[:, S]).max()) < 2e-2


def test_bp_baseline_step(key):
    cfg = get_config("qwen2-0.5b").reduced()
    params = transformer.init(key, cfg)
    opt = optim.adam_init(params)
    batch = _batch(cfg, key)
    step_fn = jax.jit(train_lib.make_bp_train_step(cfg, lr=1e-3))
    p2, o2, metrics = step_fn(params, opt, batch, 1)
    assert bool(jnp.isfinite(metrics["loss_ce"]))
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(p2))


def test_perf_opt_goodness_step(key):
    import dataclasses
    cfg = get_config("tinyllama-1.1b").reduced()
    cfg = dataclasses.replace(
        cfg, ff=dataclasses.replace(cfg.ff, goodness="perf_opt"))
    params = transformer.init(key, cfg)
    opt = optim.adam_init(params)
    batch = _batch(cfg, key)
    step_fn = jax.jit(train_lib.make_ff_train_step(cfg, lr=1e-3))
    p2, _, metrics = step_fn(params, opt, batch, 1)
    assert bool(jnp.isfinite(metrics["loss_ce"]))


def test_adaptive_neg_mode_step(key):
    import dataclasses
    cfg = get_config("qwen2-0.5b").reduced()
    cfg = dataclasses.replace(
        cfg, ff=dataclasses.replace(cfg.ff, neg_mode="adaptive"))
    params = transformer.init(key, cfg)
    opt = optim.adam_init(params)
    batch = _batch(cfg, key)
    step_fn = jax.jit(train_lib.make_ff_train_step(cfg, lr=1e-3))
    p2, _, metrics = step_fn(params, opt, batch, 1)
    assert bool(jnp.isfinite(metrics["loss_ff"]))


def test_ff_learns_on_lm(key):
    """FF loss must fall over a few steps (the per-batch goodness gap is
    noisy because negatives resample every step; the loss is the robust
    monotone signal)."""
    from repro import data as data_lib
    cfg = get_config("qwen2-0.5b").reduced()
    params = transformer.init(key, cfg)
    opt = optim.adam_init(params)
    step_fn = jax.jit(train_lib.make_ff_train_step(cfg, lr=1e-3))
    losses = []
    for i, tokens in enumerate(data_lib.lm_batches(cfg.vocab, 8, 48, 16)):
        params, opt, metrics = step_fn(
            params, opt, {"tokens": jnp.asarray(tokens)}, i + 1)
        losses.append(float(metrics["loss_ff"]))
    assert min(losses[-4:]) < losses[0], losses
