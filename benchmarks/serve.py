"""Serving subsystem benchmark: latency SLOs + hot-swap soundness.

Measures what ``repro.serve`` (continuous batching over the fused
kernel path + live ``WeightBus`` hot-swap) delivers and proves what it
promises, writing ``BENCH_serve.json`` (``make serve-smoke``):

  1. static replay — a trained snapshot served under bursty traffic at
     an overload rate: p50/p99 latency, throughput, shed rate, and a
     bit-identical double-replay gate (same seed -> same (id, label,
     pred) stream; the determinism the traffic generators owe).
  2. train-while-serve — the all_layers N=4 executor run with live
     per-layer publication while a replica serves zipf traffic from
     the same bus: swap timeline (one hot-swap per chapter plus the
     initial snapshot), staleness-at-swap, and the accuracy-vs-time
     curve keyed by installed version. Gates: ZERO version-vector
     consistency violations, >= splits hot-swaps, and the curve must
     climb (final window accuracy beats the first window and lands
     above 0.4 — live swaps actually improve answers mid-run).
  3. p99 regression bound — the static-replay p99 is checked against
     the bound recorded in an existing ``BENCH_serve.json`` (first run
     records ``max(2000ms, 10x measured)``; later runs keep the bound
     and fail if measured p99 exceeds it).

Needs >= 4 devices (export
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` before jax is
imported; this module sets it when imported first, and ``make
serve-smoke`` always does). With fewer devices an existing
``BENCH_serve.json`` is kept rather than clobbered — same policy as
``benchmarks/pff_exec.py`` / ``benchmarks/pff_faults.py``.
"""
from __future__ import annotations

import json
import os
import sys

if "jax" not in sys.modules:                       # pragma: no cover
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax

from repro import api, data as data_lib
from repro.configs.ff_mlp import FFMLPConfig

# the floor any fresh p99 bound is clamped to: CPU-container wall
# clocks under CI load are noisy, sub-second bounds would flake
_P99_FLOOR_MS = 2000.0
_P99_SLACK = 10.0


def _replay_key(res):
    return [(r["id"], r["label"], r["pred"]) for r in res.records]


def run(quick=True, out_path=None):
    if out_path is None:
        out_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                os.pardir, "BENCH_serve.json")
    splits, epochs, n_train = (4, 100, 2560) if quick else (6, 120, 4096)
    task = data_lib.mnist_like(n_train=n_train, n_test=400)
    cfg = FFMLPConfig(layer_sizes=(task.dim, 256, 256), epochs=epochs,
                      splits=splits, neg_mode="random",
                      classifier="goodness", batch_size=64, seed=0)
    devices = jax.devices()
    n_dev = len(devices)
    print(f"devices: {n_dev} x {devices[0].platform}")
    prior = None
    if os.path.exists(out_path):
        try:
            with open(out_path) as f:
                prior = json.load(f)
        except (OSError, ValueError):
            prior = None
    results = {
        "config": {"n_train": n_train, "splits": splits, "epochs": epochs,
                   "layer_sizes": list(cfg.layer_sizes),
                   "backend": jax.default_backend(), "devices": n_dev,
                   "cpu_count": os.cpu_count()},
        "failures": [],
    }
    if n_dev < 4:
        msg = (f"needs 4 devices, found {n_dev} — set XLA_FLAGS="
               "--xla_force_host_platform_device_count=4 "
               "(see make serve-smoke)")
        print(msg)
        if prior is not None:
            print(f"keeping existing {os.path.normpath(out_path)}")
        else:
            results["note"] = msg
            with open(out_path, "w") as f:
                json.dump(results, f, indent=2)
        return results
    failures = results["failures"]

    # ---- 1. static replay: latency under overload + determinism ---------
    trained = api.fit(cfg, task, backend="sequential")
    print(f"trained snapshot: acc {trained.test_acc:.4f}")

    def _replay():
        return api.serve(cfg, task, params=trained.params,
                         traffic="bursty", rate=2000.0, n_requests=256,
                         max_batch=cfg.batch_size, seed=5)

    _replay()                                    # compile + warm caches
    static = _replay()
    if _replay_key(static) != _replay_key(_replay()):
        failures.append("static replay is not deterministic: same seed "
                        "produced a different (id, label, pred) stream")
    results["static"] = {"slo": static.slo,
                         "deterministic": not failures}
    s = static.slo
    print(f"static bursty@2000rps: {s['requests']} req "
          f"p50={s['latency_p50_ms']:.1f}ms p99={s['latency_p99_ms']:.1f}ms "
          f"{s['throughput_rps']:.0f} rps shed={s['shed_rate']:.3f} "
          f"acc={s['accuracy']:.3f}")

    # ---- 2. train-while-serve: hot-swap soundness + accuracy curve -----
    live = api.serve(cfg, task, traffic="zipf", schedule="all_layers",
                     num_nodes=4, devices=devices, rate=300.0,
                     max_batch=cfg.batch_size, seed=1)
    slo = live.slo
    curve = live.accuracy_by_version
    results["live"] = {
        "slo": slo,
        "train_acc": live.fit.test_acc,
        "train_makespan_s": live.fit.makespan,
        "timings": live.timings,
        "swap_timeline": live.swaps,
        "accuracy_by_version": curve,
    }
    if slo["consistency_violations"]:
        failures.append(f"live serve: {slo['consistency_violations']} "
                        "version-vector consistency violations (must be 0)")
    if slo["swaps"] < splits:
        failures.append(f"live serve: only {slo['swaps']} hot-swaps for "
                        f"{splits} chapters (want >= 1 per chapter)")
    vs = sorted(curve)
    first, last = curve[vs[0]], curve[vs[-1]]
    if last["accuracy"] <= first["accuracy"] or last["accuracy"] < 0.4:
        failures.append(
            f"live serve: accuracy-vs-time curve did not climb "
            f"(v{vs[0]}: {first['accuracy']:.3f} -> "
            f"v{vs[-1]}: {last['accuracy']:.3f})")
    print(f"train-while-serve all_layers N=4: train acc "
          f"{live.fit.test_acc:.4f} in {live.fit.makespan:.1f}s")
    print(f"  served {slo['requests']} req  swaps={slo['swaps']} "
          f"staleness_max={slo['staleness_max_s']:.3f}s "
          f"violations={slo['consistency_violations']}")
    for v in vs:
        print(f"    version {v:3d}: n={curve[v]['n']:5d} "
              f"acc={curve[v]['accuracy']:.3f}")

    # ---- 3. p99 regression bound ---------------------------------------
    p99 = s["latency_p99_ms"]
    bound = (prior or {}).get("p99_bound_ms")
    if bound is None:
        bound = max(_P99_FLOOR_MS, _P99_SLACK * p99)
        print(f"recording fresh p99 bound {bound:.0f}ms "
              f"(measured {p99:.1f}ms)")
    elif p99 > bound:
        failures.append(f"static p99 {p99:.1f}ms exceeds the recorded "
                        f"bound {bound:.0f}ms")
    else:
        print(f"static p99 {p99:.1f}ms within recorded bound "
              f"{bound:.0f}ms")
    results["p99_bound_ms"] = bound

    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {os.path.normpath(out_path)}")
    return results
